"""Serving-fleet suite: paged pool, continuous-batching scheduler, the
multi-tenant engine, and live cross-flavor migration.

Fast tier: pool/scheduler unit edge cases (OOM -> preempt-lowest-priority,
preempt-then-readmit byte-identical, zero-length prompt, defrag preserves
contents, all-sessions-retire-same-step) plus the kernel_view parity check
against the dense decode-attention reference.

Slow tier (``-m slow``): engine end-to-end — continuous batching vs the
single-stream ``Server`` reference, fleet checkpoint/restore across
flavors, live migration mid-sequence (byte-identical continuation,
torn-transfer rejection, >1-page sessions).
"""
from dataclasses import replace

import numpy as np
import pytest

from repro.configs import smoke_config
from repro.serving.kv_pool import PagePool, PoolOOMError
from repro.serving.scheduler import (DONE, MIGRATED, QUEUED, RUNNING,
                                     ContinuousBatchScheduler)


def tiny_cfg():
    return replace(smoke_config("granite-3-2b"), n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                   vocab_size=256, vocab_pad_multiple=64)


# ---------------------------------------------------------------------------
# fast: page pool
# ---------------------------------------------------------------------------

def test_pool_admit_write_read_roundtrip(rng):
    p = PagePool(8, 4)
    p.admit("a", 6)
    rows = rng.standard_normal((6, 3)).astype(np.float32)
    p.write_tokens("a", 0, {"k": rows})
    p.write_blocks("a", {"ssm": np.ones((2, 5), np.float32)})
    np.testing.assert_array_equal(p.read_tokens("a")["k"], rows)
    np.testing.assert_array_equal(p.read_blocks("a")["ssm"],
                                  np.ones((2, 5), np.float32))
    assert p.used_pages == 2 and p.sessions["a"].length == 6


def test_pool_zero_length_admission_owns_no_pages():
    p = PagePool(4, 4)
    p.admit("z", 0)
    assert p.used_pages == 0 and p.sessions["z"].length == 0
    assert p.read_tokens("z") == {}
    # first decode grows it onto its first page
    p.write_tokens("z", 0, {"k": np.ones((1, 2), np.float32)})
    assert p.used_pages == 1 and p.sessions["z"].length == 1


def test_pool_growth_crosses_page_boundary():
    p = PagePool(4, 2)
    p.admit("a", 2)
    assert len(p.sessions["a"].pages) == 1
    for t in range(2, 5):
        p.write_tokens("a", t, {"k": np.full((1, 1), t, np.float32)})
    assert len(p.sessions["a"].pages) == 3
    np.testing.assert_array_equal(
        p.read_tokens("a")["k"][2:, 0], [2.0, 3.0, 4.0])


def test_pool_oom_and_victim_policy():
    p = PagePool(4, 4)
    p.admit("low", 8, priority=0)
    p.admit("high", 8, priority=5)
    with pytest.raises(PoolOOMError):
        p.admit("newcomer", 4, priority=3)
    # victim: strictly below the candidate's priority -> only "low"
    assert p.preempt_victim(below_priority=3) == "low"
    # nothing strictly below 0 -> no victim, candidate must wait
    assert p.preempt_victim(below_priority=0) is None
    # unrestricted: lowest priority wins; newest arrival among ties
    p2 = PagePool(4, 4)
    p2.admit("old", 4, priority=1)
    p2.admit("new", 4, priority=1)
    assert p2.preempt_victim() == "new"


def test_pool_park_unpark_byte_identical(rng):
    p = PagePool(6, 4)
    p.admit("a", 9, priority=2)
    rows = rng.standard_normal((9, 4)).astype(np.float32)
    p.write_tokens("a", 0, {"k": rows})
    p.write_blocks("a", {"conv": rng.standard_normal((3,)).astype(np.float32)})
    before = p.export_session("a")
    p.park("a")
    assert "a" not in p.sessions and p.free_pages == 6
    p.unpark("a")
    after = p.export_session("a")
    np.testing.assert_array_equal(before["tokens"]["k"], after["tokens"]["k"])
    np.testing.assert_array_equal(before["blocks"]["conv"],
                                  after["blocks"]["conv"])
    assert before["table"]["length"] == after["table"]["length"]


def test_pool_unpark_oom_leaves_payload_parked():
    p = PagePool(2, 4)
    p.admit("a", 8)
    p.write_tokens("a", 0, {"k": np.ones((8, 1), np.float32)})
    p.park("a")
    p.admit("b", 8)       # pool now full
    with pytest.raises(PoolOOMError):
        p.unpark("a")
    assert "a" in p.parked     # nothing lost
    p.release("b")
    p.unpark("a")
    assert p.sessions["a"].length == 8


def test_pool_import_session_preserves_arrival_seq():
    p = PagePool(8, 4)
    p.admit("old", 4, priority=1)
    p.admit("vic", 4, priority=1)
    p.park("vic")
    p.admit("new", 4, priority=1)     # arrives after vic was parked
    p.unpark("vic")
    # vic keeps its ORIGINAL arrival position: "new" stays the
    # newest-arrival tie-break victim after the swap round-trip
    assert p.sessions["vic"].seq < p.sessions["new"].seq
    assert p.preempt_victim() == "new"
    # _seq stays monotonic past the restored seq
    p.admit("next", 0)
    assert p.sessions["next"].seq > p.sessions["new"].seq


def test_pool_defrag_preserves_contents(rng):
    p = PagePool(8, 2)
    p.admit("a", 4)
    p.admit("b", 4)
    p.admit("c", 4)
    content = {s: rng.standard_normal((4, 3)).astype(np.float32)
               for s in ("a", "b", "c")}
    for s, rows in content.items():
        p.write_tokens(s, 0, {"k": rows})
    p.release("b")        # hole in the middle
    r = p.defrag()
    assert r["moved"] > 0
    assert r["used"] == 4 and p.free_pages == 4
    # compacted pages are the low indices
    used = sorted(pg for s in p.sessions.values() for pg in s.pages)
    assert used == list(range(4))
    for s in ("a", "c"):
        np.testing.assert_array_equal(p.read_tokens(s)["k"], content[s])


def test_pool_export_import_state_roundtrip(rng):
    p = PagePool(8, 4)
    p.admit("a", 6, priority=1)
    p.write_tokens("a", 0, {"k": rng.standard_normal((6, 2)).astype(np.float32)})
    p.admit("b", 3)
    p.write_tokens("b", 0, {"k": rng.standard_normal((3, 2)).astype(np.float32)})
    p.write_blocks("b", {"ssm": np.ones((2, 2), np.float32)})
    p.park("b")           # parked sessions must ride snapshots too
    arrays, table = p.export_state()
    assert "parked:b" in arrays
    q = PagePool(8, 4)
    q.import_state(arrays, table)
    np.testing.assert_array_equal(q.read_tokens("a")["k"],
                                  p.read_tokens("a")["k"])
    assert q.sessions["a"].pages == p.sessions["a"].pages   # exact layout
    np.testing.assert_array_equal(q.parked["b"]["tokens"]["k"],
                                  p.parked["b"]["tokens"]["k"])


def test_pool_truncate_frees_tail_pages():
    p = PagePool(4, 2)
    p.admit("a", 7)
    p.write_tokens("a", 0, {"k": np.arange(7, dtype=np.float32)[:, None]})
    assert p.used_pages == 4
    p.truncate("a", 3)
    assert p.sessions["a"].length == 3 and p.used_pages == 2
    np.testing.assert_array_equal(p.read_tokens("a")["k"][:, 0],
                                  [0.0, 1.0, 2.0])


def test_kernel_view_matches_dense_decode_attention(rng):
    import jax.numpy as jnp
    from repro.kernels.decode_attention import (decode_attention,
                                               paged_attention_pool_view)
    K, D, H = 2, 8, 4
    p = PagePool(16, 4)
    lens = {"s0": 6, "s1": 11}
    kv = {}
    for sid, L in lens.items():
        p.admit(sid, L)
        kv[sid] = (rng.standard_normal((L, K * D)).astype(np.float32),
                   rng.standard_normal((L, K * D)).astype(np.float32))
        p.write_tokens(sid, 0, {"k": kv[sid][0], "v": kv[sid][1]})
    q = rng.standard_normal((2, H, D)).astype(np.float32)
    view = p.kernel_view(["s0", "s1"], "k", "v", K, D)
    got = np.asarray(paged_attention_pool_view(q, view, interpret=True))
    S = max(lens.values())
    for b, sid in enumerate(["s0", "s1"]):
        L = lens[sid]
        kd = np.zeros((1, S, K, D), np.float32)
        vd = np.zeros((1, S, K, D), np.float32)
        kd[0, :L] = kv[sid][0].reshape(L, K, D)
        vd[0, :L] = kv[sid][1].reshape(L, K, D)
        ref = decode_attention(jnp.asarray(q[b : b + 1]), jnp.asarray(kd),
                               jnp.asarray(vd), jnp.asarray([L], jnp.int32),
                               interpret=True)
        np.testing.assert_allclose(got[b], np.asarray(ref)[0],
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fast: scheduler
# ---------------------------------------------------------------------------

def test_scheduler_priority_then_fifo():
    s = ContinuousBatchScheduler(max_running=2)
    s.submit("a", priority=0)
    s.submit("b", priority=5)
    s.submit("c", priority=0)
    assert s.queued() == ["b", "a", "c"]
    s.admitted(s.next_admission())
    s.admitted(s.next_admission())
    assert s.running == ["b", "a"]
    assert s.next_admission() is None          # lanes full


def test_scheduler_preempted_keeps_arrival_seq():
    s = ContinuousBatchScheduler(max_running=1)
    s.submit("a")
    s.submit("b")
    s.admitted("a")
    s.preempted("a")
    # a re-queues AHEAD of b (original seq), not at the back
    assert s.queued() == ["a", "b"]
    assert s.tickets["a"].preemptions == 1


def test_scheduler_all_retire_same_step_frees_every_lane():
    s = ContinuousBatchScheduler(max_running=3)
    for sid in ("a", "b", "c"):
        s.submit(sid)
        s.admitted(sid)
    for sid in ("a", "b", "c"):
        s.retired(sid)
    assert s.running == [] and s.lanes_free() == 3
    assert not s.live()
    assert all(s.state(x) == DONE for x in ("a", "b", "c"))


def test_scheduler_snapshot_restore_roundtrip():
    s = ContinuousBatchScheduler(max_running=2)
    s.submit("a", priority=3)
    s.submit("b")
    s.admitted("a")
    s.submit("m")
    s.admitted("m")
    s.migrated("m")
    snap = s.snapshot()
    t = ContinuousBatchScheduler()
    t.restore(snap)
    assert t.running == ["a"] and t.state("b") == QUEUED
    assert t.state("m") == MIGRATED and t._seq == s._seq
    assert t.queued() == ["b"]


def test_scheduler_duplicate_submit_rejected():
    s = ContinuousBatchScheduler()
    s.submit("a")
    with pytest.raises(ValueError):
        s.submit("a")


# ---------------------------------------------------------------------------
# fast: migration receiver applies the re-encoded leaf descriptors
# ---------------------------------------------------------------------------

class _StubLink:
    def __init__(self, msgs):
        self.msgs = list(msgs)
        self.acks = []

    def recv_at_dst(self):
        return self.msgs.pop(0)

    def ack_to_src(self, msg):
        self.acks.append(msg)

    def recv_ack(self):
        return self.acks[-1]


class _StubEngine:
    def __init__(self):
        self.imported = []

    def import_session_state(self, sid, state):
        self.imported.append((sid, state))


class _StubPlan:
    runtime = {}


def _session_stream(leaf_dtype="float32", leaf_shape=(2, 3)):
    from repro.core.ckpt_tiers import container_sha
    arr = np.ones((2, 3), np.float32)
    data = arr.tobytes()
    header = {"op": "session", "sid": "s", "cursor": {"prompt": [1, 2]},
              "sched_state": RUNNING, "parked": False,
              "table": {"length": 2, "priority": 0, "seq": 1},
              "leaves": [{"name": "tokens/k", "dtype": leaf_dtype,
                          "shape": list(leaf_shape),
                          "mpi_dtype": "MPI_CHAR"}]}
    chunk = {"op": "chunk", "sid": "s", "section": "tokens", "key": "k",
             "data": data, "dtype": "float32", "shape": [2, 3],
             "sha": container_sha(data)}
    return [header, chunk, {"op": "commit", "sid": "s", "count": 1}]


def test_receive_session_rejects_descriptor_mismatch():
    from repro.serving import migrate as M
    eng = _StubEngine()
    rep = M.MigrationReport(src_flavor="a", dst_flavor="b")
    ack = M._receive_session(_StubLink(_session_stream("float64")), eng,
                             _StubPlan(), rep)
    assert not ack["ok"] and "tokens/k" in ack["error"]
    assert eng.imported == []        # refused before any half-import


def test_receive_session_accepts_matching_descriptors():
    from repro.serving import migrate as M
    eng = _StubEngine()
    rep = M.MigrationReport(src_flavor="a", dst_flavor="b")
    ack = M._receive_session(_StubLink(_session_stream()), eng,
                             _StubPlan(), rep)
    assert ack["ok"]
    (sid, state), = eng.imported
    assert sid == "s"
    np.testing.assert_array_equal(state["pool"]["tokens"]["k"],
                                  np.ones((2, 3), np.float32))


# ---------------------------------------------------------------------------
# fast: warn_skipped (satellite: silently-ignored providers)
# ---------------------------------------------------------------------------

def test_warn_skipped_prints_once_and_returns_line(capsys):
    from repro.core import runtime_state as RS
    line = RS.warn_skipped({"providers": 2, "skipped": ["ghost", "old"]},
                           "serve")
    out = capsys.readouterr().out
    assert "ghost" in out and "old" in out and "serve" in out
    assert "WARNING" in out and line is not None
    assert RS.warn_skipped({"providers": 2, "skipped": []}, "serve") is None
    assert capsys.readouterr().out == ""
    assert RS.warn_skipped(None, "serve") is None


# ---------------------------------------------------------------------------
# slow: engine end-to-end + migration
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_matches_single_stream_server(rng):
    from repro.serving.engine import ServeEngine, Server
    cfg = tiny_cfg()
    prompt = rng.integers(0, 256, 6, dtype=np.int32)
    srv = Server(cfg, backend="mpich", seed=0)
    logits = srv.prefill(prompt[None, :], pad_to=24)
    tok0 = int(np.argmax(np.asarray(logits)[0, : cfg.vocab_size]))
    toks, _ = srv.decode(7, np.asarray([tok0], np.int32))
    ref = [tok0] + [int(t[0]) for t in toks]

    eng = ServeEngine(cfg, backend="mpich", seed=0, max_len=24,
                      page_size=4, n_pages=32, max_running=3)
    a = eng.submit(prompt, max_new_tokens=8)
    b = eng.submit(rng.integers(0, 256, 3), max_new_tokens=6)
    z = eng.submit([], max_new_tokens=4)        # zero-length prompt
    eng.run_until_drained(max_ticks=60)
    assert eng.stream(a) == ref                 # continuous batching is
    assert len(eng.stream(b)) == 6              # invisible to each stream
    assert len(eng.stream(z)) == 4
    assert not eng.sched.live()


@pytest.mark.slow
def test_engine_preempt_readmit_byte_identical(rng):
    from repro.serving.engine import ServeEngine, Server
    cfg = tiny_cfg()
    prompt = rng.integers(0, 256, 6, dtype=np.int32)
    srv = Server(cfg, backend="mpich", seed=0)
    logits = srv.prefill(prompt[None, :], pad_to=24)
    tok0 = int(np.argmax(np.asarray(logits)[0, : cfg.vocab_size]))
    toks, _ = srv.decode(7, np.asarray([tok0], np.int32))
    ref = [tok0] + [int(t[0]) for t in toks]

    # pool too small for both sessions: the high-priority arrival must
    # swap the low one out, and its readmitted stream must not fork
    eng = ServeEngine(cfg, backend="mpich", seed=0, max_len=24,
                      page_size=4, n_pages=6, max_running=2)
    a = eng.submit(prompt, max_new_tokens=8, priority=0)
    for _ in range(3):
        eng.step_once()
    b = eng.submit(rng.integers(0, 256, 8), max_new_tokens=6, priority=5)
    eng.run_until_drained(max_ticks=200)
    assert eng.sched.tickets[a].preemptions >= 1
    assert eng.stream(a) == ref
    assert len(eng.stream(b)) == 6


@pytest.mark.slow
def test_engine_checkpoint_restore_cross_flavor(rng, tmp_path):
    from repro.serving.engine import ServeEngine
    cfg = tiny_cfg()
    prompt = rng.integers(0, 256, 6, dtype=np.int32)
    eng = ServeEngine(cfg, backend="mpich", seed=0, max_len=24,
                      page_size=4, n_pages=32, ckpt_dir=tmp_path)
    s1 = eng.submit(prompt, max_new_tokens=8)
    s2 = eng.submit(rng.integers(0, 256, 3), max_new_tokens=6)
    for _ in range(3):
        eng.step_once()
    eng.checkpoint().wait()
    mid = {s: list(eng.stream(s)) for s in (s1, s2)}
    eng.run_until_drained()
    full = {s: eng.stream(s) for s in (s1, s2)}

    fresh = ServeEngine(cfg, backend="fabric", seed=0, max_len=24,
                        page_size=4, n_pages=32, ckpt_dir=tmp_path)
    assert fresh.resume_latest() is not None
    assert {s: fresh.stream(s) for s in (s1, s2)} == mid
    fresh.run_until_drained()
    assert {s: fresh.stream(s) for s in (s1, s2)} == full
    assert fresh.last_runtime_restore["skipped"] == []


@pytest.mark.slow
def test_live_migration_cross_flavor_byte_identical(rng):
    from repro.serving import ServeEngine, migrate_sessions
    cfg = tiny_cfg()
    prompt = rng.integers(0, 256, 6, dtype=np.int32)
    long_prompt = rng.integers(0, 256, 11, dtype=np.int32)  # spans 3 pages

    ref_eng = ServeEngine(cfg, backend="mpich", seed=0, max_len=24,
                          page_size=4, n_pages=32)
    r1 = ref_eng.submit(prompt, max_new_tokens=8)
    r2 = ref_eng.submit(long_prompt, max_new_tokens=6)
    ref_eng.run_until_drained()

    src = ServeEngine(cfg, backend="mpich", seed=0, max_len=24,
                      page_size=4, n_pages=32)
    a = src.submit(prompt, max_new_tokens=8)
    b = src.submit(long_prompt, max_new_tokens=6)
    for _ in range(3):
        src.step_once()
    dst = ServeEngine(cfg, backend="fabric", seed=0, max_len=24,
                      page_size=4, n_pages=32)
    rep = migrate_sessions(src, dst, [a, b])
    assert rep.sessions == [a, b] and rep.chunks > 0
    assert src.sched.state(a) == MIGRATED and not src.sched.live()
    dst.run_until_drained()
    assert dst.stream(a) == ref_eng.stream(r1)   # gap- and duplicate-free
    assert dst.stream(b) == ref_eng.stream(r2)


@pytest.mark.slow
def test_submit_rejects_overrunning_max_len(rng):
    from repro.serving.engine import ServeEngine
    cfg = tiny_cfg()
    eng = ServeEngine(cfg, backend="mpich", seed=0, max_len=12,
                      page_size=4, n_pages=8)
    with pytest.raises(ValueError):
        eng.submit(rng.integers(0, 256, 12, dtype=np.int32))  # >= max_len
    with pytest.raises(ValueError):
        # 6-token prompt + 8 generated needs 13 cache rows > max_len 12
        eng.submit(rng.integers(0, 256, 6, dtype=np.int32), max_new_tokens=8)
    with pytest.raises(ValueError):
        eng.submit([], max_new_tokens=13)  # zero-length: max_new > max_len
    # exact fits are accepted
    eng.submit(rng.integers(0, 256, 6, dtype=np.int32), max_new_tokens=7)
    eng.submit([], max_new_tokens=12)


@pytest.mark.slow
def test_decode_growth_beyond_pool_capacity_raises(rng):
    from repro.serving.engine import ServeEngine
    cfg = tiny_cfg()
    # the pool holds 2 token positions TOTAL: session a's first decode
    # needs a second page that does not exist.  With only page-less
    # QUEUED b around, self-parking would free nothing (park/unpark
    # livelock); the engine must raise instead of spinning to max_ticks
    eng = ServeEngine(cfg, backend="mpich", seed=0, max_len=8,
                      page_size=2, n_pages=1, max_running=2)
    eng.submit(rng.integers(0, 256, 2, dtype=np.int32), max_new_tokens=4)
    eng.submit(rng.integers(0, 256, 2, dtype=np.int32), max_new_tokens=2)
    with pytest.raises(PoolOOMError):
        eng.run_until_drained(max_ticks=50)


@pytest.mark.slow
def test_migration_into_busy_destination_queues_then_runs(rng):
    from repro.serving import ServeEngine, migrate_sessions
    cfg = tiny_cfg()
    prompt = rng.integers(0, 256, 6, dtype=np.int32)

    ref = ServeEngine(cfg, backend="mpich", seed=0, max_len=24,
                      page_size=4, n_pages=32)
    r = ref.submit(prompt, max_new_tokens=8)
    ref.run_until_drained()

    src = ServeEngine(cfg, backend="mpich", seed=0, max_len=24,
                      page_size=4, n_pages=32)
    a = src.submit(prompt, sid="mig-a", max_new_tokens=8)
    for _ in range(3):
        src.step_once()
    # destination has ONE lane and it is already occupied: the migrated
    # session must land pool-resident but QUEUED, then take the lane when
    # the busy session retires — without re-prefilling into the pool
    dst = ServeEngine(cfg, backend="fabric", seed=0, max_len=24,
                      page_size=4, n_pages=32, max_running=1)
    busy = dst.submit(rng.integers(0, 256, 4, dtype=np.int32),
                      max_new_tokens=6)
    dst.step_once()
    assert dst.sched.lanes_free() == 0
    migrate_sessions(src, dst, [a])
    assert dst.sched.state(a) == QUEUED and a in dst.pool.sessions
    dst.run_until_drained(max_ticks=100)
    assert dst.stream(a) == ref.stream(r)   # gap- and duplicate-free
    assert len(dst.stream(busy)) == 6


@pytest.mark.slow
def test_migration_torn_transfer_rejected(rng):
    from repro.core import faults as F
    from repro.serving import MigrationError, ServeEngine, migrate_sessions
    cfg = tiny_cfg()
    prompt = rng.integers(0, 256, 6, dtype=np.int32)
    src = ServeEngine(cfg, backend="mpich", seed=0, max_len=24,
                      page_size=4, n_pages=32)
    ref = ServeEngine(cfg, backend="mpich", seed=0, max_len=24,
                      page_size=4, n_pages=32)
    a = src.submit(prompt, max_new_tokens=8)
    ra = ref.submit(prompt, max_new_tokens=8)
    for _ in range(2):
        src.step_once()
    ref.run_until_drained()
    dst = ServeEngine(cfg, backend="fabric", seed=0, max_len=24,
                      page_size=4, n_pages=32)

    def flip(name, ctx):
        m = ctx["msg"]
        m["data"] = bytes([m["data"][0] ^ 0xFF]) + m["data"][1:]
        F.disarm("serve.migrate.chunk", flip)

    F.arm("serve.migrate.chunk", flip)
    try:
        with pytest.raises(MigrationError):
            migrate_sessions(src, dst, [a])
    finally:
        F.disarm("serve.migrate.chunk")
    # at-most-once placement: still live at source, absent at destination
    assert src.sched.state(a) == RUNNING
    assert a not in dst.sessions
    src.run_until_drained()
    assert src.stream(a) == ref.stream(ra)


@pytest.mark.slow
def test_migrate_corrupt_fault_kind_fires_failpoint():
    from repro.core.faults import (FAULT_KINDS, FaultInjector, FaultPlan,
                                   FaultSpec, failpoint)
    assert "migrate_corrupt" in FAULT_KINDS
    class _StubCluster:
        def __init__(self):
            self.events = []

    plan = FaultPlan([FaultSpec(kind="migrate_corrupt", at_step=0)])
    with FaultInjector(plan) as inj:
        inj.on_step(0, _StubCluster())
        msg = {"data": b"\x00" * 8, "sha": "irrelevant"}
        failpoint("serve.migrate.chunk", msg=msg)
        assert msg["data"] != b"\x00" * 8          # bytes flipped
        msg2 = {"data": b"\x00" * 8}
        failpoint("serve.migrate.chunk", msg=msg2)
        assert msg2["data"] == b"\x00" * 8         # one-shot
