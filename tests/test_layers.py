"""Layer-level numerics: chunked attention schedules vs naive oracle, MoE
dispatch invariants, recurrences vs naive references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import pytest as _pytest
_pytest.importorskip("hypothesis")  # optional dep: skip, not error
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig, ModelConfig
from repro.kernels import ref
from repro.models import layers as L
from repro.models import ssm as S
from repro.sharding import ShardingCtx

CTX = ShardingCtx(None, {})


def _qkv(key, B, H, K, S, D):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("schedule", ["masked", "triangular"])
@pytest.mark.parametrize("window", [None, 16])
def test_chunked_attention_matches_naive(schedule, window):
    B, H, K, S, D = 2, 4, 2, 64, 32
    q, k, v = _qkv(jax.random.key(0), B, H, K, S, D)
    kr = jnp.repeat(k, H // K, axis=2)
    vr = jnp.repeat(v, H // K, axis=2)
    out = L.chunked_attention(CTX, q, kr, vr, window=window, schedule=schedule,
                              q_chunk=16, kv_chunk=16)
    want = ref.naive_attention(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                               jnp.moveaxis(v, 1, 2), window=window)
    want = jnp.moveaxis(want, 1, 2)        # -> [B,S,H,D]
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


def test_triangular_schedule_equals_masked():
    B, H, K, S, D = 1, 2, 2, 128, 16
    q, k, v = _qkv(jax.random.key(1), B, H, K, S, D)
    a = L.chunked_attention(CTX, q, k, v, schedule="masked", q_chunk=32, kv_chunk=32)
    b = L.chunked_attention(CTX, q, k, v, schedule="triangular", q_chunk=32,
                            kv_chunk=32)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_decode_attention_matches_naive():
    B, H, K, S, D = 2, 4, 2, 64, 16
    ks = jax.random.split(jax.random.key(2), 5)
    q = jax.random.normal(ks[0], (B, H * D))
    kc = jax.random.normal(ks[1], (B, S, K * D))
    vc = jax.random.normal(ks[2], (B, S, K * D))
    k_new = jax.random.normal(ks[3], (B, K * D))
    v_new = jax.random.normal(ks[4], (B, K * D))
    pos = 37
    out, kc2, vc2 = L.decode_attention(CTX, q, kc, vc, k_new, v_new, pos,
                                       n_kv_heads=K)
    # the row write happened
    np.testing.assert_allclose(kc2[:, pos], k_new, rtol=1e-6)
    want = ref.naive_decode_attention(
        q.reshape(B, K, H // K, D).reshape(B, H, D) if False else
        q.reshape(B, H, D),
        jnp.moveaxis(kc2.reshape(B, S, K, D), 1, 2),
        jnp.moveaxis(vc2.reshape(B, S, K, D), 1, 2), pos + 1)
    got = out.reshape(B, H, D)
    # NB: decode_attention's head layout is [K, G]; naive uses [H] = K-major, same
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_ring_buffer_window_decode():
    B, K, D, W = 1, 2, 8, 16
    H = 4
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, H * D))
    kc = jax.random.normal(ks[1], (B, W, K * D))
    vc = jax.random.normal(ks[2], (B, W, K * D))
    pos = 21  # ring has wrapped
    out = L.window_decode_attention(q, kc, vc, pos, n_kv_heads=K, window=W)
    kpos = L.ring_slot_positions(pos, W)
    assert int(kpos.max()) == pos and int(kpos.min()) == pos - W + 1
    assert out.shape == (B, H * D)
    assert np.isfinite(np.asarray(out)).all()


def test_rope_is_position_shift_equivariant_in_scores():
    """RoPE property: q_i . k_j depends only on i - j."""
    D = 16
    q = jax.random.normal(jax.random.key(4), (1, 1, 1, D))
    k = jax.random.normal(jax.random.key(5), (1, 1, 1, D))
    def score(i, j):
        qi = L.rope(q, jnp.array([i]), 10000.0)
        kj = L.rope(k, jnp.array([j]), 10000.0)
        return float(jnp.sum(qi * kj))
    assert abs(score(5, 3) - score(105, 103)) < 1e-4
    assert abs(score(5, 3) - score(6, 3)) > 1e-6


def test_causal_conv_matches_step_decode():
    B, S, C, W = 2, 10, 6, 4
    x = jax.random.normal(jax.random.key(6), (B, S, C))
    w = jax.random.normal(jax.random.key(7), (W, C))
    full = L.causal_conv1d(x, w)
    state = jnp.zeros((B, W - 1, C))
    outs = []
    for t in range(S):
        o, state = L.causal_conv1d_step(x[:, t], state, w)
        outs.append(o)
    np.testing.assert_allclose(full, jnp.stack(outs, 1), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE dispatch invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10000), st.integers(2, 8), st.integers(1, 4))
def test_moe_dispatch_invariants(seed, E, k):
    k = min(k, E)
    B, s, C = 2, 16, 4
    gates = jax.nn.softmax(
        jax.random.normal(jax.random.key(seed), (B, s, E)), axis=-1)
    dispatch, combine, first = L._topk_dispatch(gates, k, C)
    d = np.asarray(dispatch)
    # each (expert, slot) holds at most one token
    assert (d.sum(axis=1) <= 1.0 + 1e-6).all()
    # each token occupies at most k slots
    assert (d.sum(axis=(2, 3)) <= k + 1e-6).all()
    # combine weights are a convex combination over kept slots
    c = np.asarray(combine).sum(axis=(2, 3))
    assert (c <= 1.0 + 1e-5).all()
    # capacity respected
    assert (d.sum(axis=(1, 3)) <= C * E + 1e-6).all()


def test_moe_capacity_drops_tokens():
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=64,
                      head_dim=8, param_dtype="float32", compute_dtype="float32",
                      moe=MoEConfig(n_experts=2, top_k=1, expert_d_ff=8,
                                    group_size=8, capacity_factor=0.5))
    from repro.models.params import init_params
    from repro.models.layers import moe_specs, moe_apply
    p = init_params(moe_specs(cfg), jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, 16))
    y, aux = moe_apply(CTX, cfg, p, x, mode="train")
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0.0   # load-balance + z losses active


# ---------------------------------------------------------------------------
# recurrences vs naive
# ---------------------------------------------------------------------------

def test_chunked_gla_matches_naive():
    B, S, H, N, P = 2, 48, 3, 8, 16
    ks = jax.random.split(jax.random.key(8), 4)
    q = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N)) * 0.3
    v = jax.random.normal(ks[2], (B, S, H, P))
    lg = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    y, h = S_chunked(q, k, v, lg)
    yn, hn = ref.naive_gla(q, k, v, lg)
    np.testing.assert_allclose(y, yn, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h, hn, rtol=1e-4, atol=1e-4)


def S_chunked(q, k, v, lg):
    return S.chunked_gla(q, k, v, lg, chunk=16)


def test_gla_step_continues_chunked():
    B, S_, H, N, P = 1, 32, 2, 8, 8
    ks = jax.random.split(jax.random.key(9), 4)
    q = jax.random.normal(ks[0], (B, S_ + 1, H, N))
    k = jax.random.normal(ks[1], (B, S_ + 1, H, N)) * 0.3
    v = jax.random.normal(ks[2], (B, S_ + 1, H, P))
    lg = -jax.nn.softplus(jax.random.normal(ks[3], (B, S_ + 1, H)))
    _, h = S.chunked_gla(q[:, :S_], k[:, :S_], v[:, :S_], lg[:, :S_], chunk=8)
    y1, _ = S.gla_step(q[:, S_], k[:, S_], v[:, S_], lg[:, S_], h)
    yn, _ = ref.naive_gla(q, k, v, lg)
    np.testing.assert_allclose(y1, yn[:, S_], rtol=1e-4, atol=1e-4)


def test_chunked_mlstm_matches_naive():
    B, S_, H, N = 2, 32, 2, 8
    ks = jax.random.split(jax.random.key(10), 5)
    q = jax.random.normal(ks[0], (B, S_, H, N))
    k = jax.random.normal(ks[1], (B, S_, H, N))
    v = jax.random.normal(ks[2], (B, S_, H, N))
    ig = jax.random.normal(ks[3], (B, S_, H))
    fg = jax.random.normal(ks[4], (B, S_, H)) + 2.0
    y, (C, n, m) = S.chunked_mlstm(q, k, v, ig, fg, chunk=8)
    yn, (Cn, nn, mn) = ref.naive_mlstm(q, k, v, ig, fg)
    np.testing.assert_allclose(y, yn, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(C, Cn, rtol=5e-4, atol=5e-4)
