"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
the same family runs one forward + one train step on CPU, asserts shapes and
finiteness; decode agrees with teacher-forced prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import steps as ST
from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import Model
from repro.optim import constant, make_optimizer
from repro.sharding import ShardingCtx, rules_for


def _batch(cfg, B, S, key, with_targets=True):
    shape = (B, cfg.n_codebooks, S) if cfg.n_codebooks > 1 else (B, S)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    b = {"tokens": toks}
    if with_targets:
        b["targets"] = jnp.roll(toks, -1, axis=-1)
    if cfg.img_tokens:
        b["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 9), (B, cfg.img_tokens, 1024), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    model = Model(cfg)
    ctx = ShardingCtx(None, rules_for(cfg, "train"))
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S, jax.random.key(1))
    logits, aux = model.train_logits(ctx, params, batch)
    want = (B, S, cfg.n_codebooks * cfg.padded_vocab)
    assert logits.shape == want
    assert np.isfinite(np.asarray(logits)).all()

    opt = make_optimizer(cfg, constant(1e-3))
    step_fn = ST.make_train_step(model, ctx, opt)
    p2, o2, metrics = jax.jit(step_fn)(params, opt.init(params), batch,
                                       jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0] - x[1]))),
        jax.tree.map(lambda a, b: (a, b), params, p2), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_prefill(arch):
    cfg = smoke_config(arch)
    model = Model(cfg)
    ctx = ShardingCtx(None, rules_for(cfg, "decode"))
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    full = _batch(cfg, B, S + 1, jax.random.key(2), with_targets=False)
    pre = {k: (v[..., :S] if v.dtype == jnp.int32 else v)
           for k, v in full.items()}
    nxt = full["tokens"][..., S]
    ref_logits, _ = model.prefill(ctx, params, full)
    _, caches = model.prefill(ctx, params, pre)

    def grow(x):
        if hasattr(x, "ndim") and x.ndim >= 3 and x.shape[-2] == S:
            pad = [(0, 0)] * x.ndim
            pad[-2] = (0, 1)
            return jnp.pad(x, pad)
        return x

    caches = jax.tree.map(grow, caches)
    dec_logits, _ = model.decode_step(ctx, params, nxt, jnp.int32(S), caches)
    scale = float(jnp.max(jnp.abs(ref_logits))) + 1e-9
    err = float(jnp.max(jnp.abs(ref_logits - dec_logits))) / scale
    assert err < 2e-2, f"{arch}: decode/prefill rel err {err}"


#: one family per cache flavor: MoE attention (granite-moe), hybrid SSM
#: (hymba), multi-codebook audio (musicgen), vision-prefix (llava)
GEN_ARCHS = ["granite-moe-3b-a800m", "hymba-1.5b", "musicgen-large",
             "llava-next-34b"]


def _greedy(cfg, logits):
    """Last-position logits [B, K*Vp] -> greedy next token [B] or [B, K]."""
    if cfg.n_codebooks > 1:
        per = logits.reshape(logits.shape[0], cfg.n_codebooks,
                             cfg.padded_vocab)[..., : cfg.vocab_size]
        return jnp.argmax(per, axis=-1).astype(jnp.int32)
    return jnp.argmax(logits[..., : cfg.vocab_size], axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", GEN_ARCHS)
def test_smoke_generation_with_cache(arch):
    """Multi-step greedy generation THROUGH the decode cache must emit the
    same tokens as re-prefilling the whole growing prefix each step."""
    from dataclasses import replace
    cfg = replace(smoke_config(arch), n_layers=2)
    model = Model(cfg)
    ctx = ShardingCtx(None, rules_for(cfg, "decode"))
    params = model.init(jax.random.key(0))
    # S must dodge non-sequence cache dims (hymba's SSM state is [..., 8, 32])
    # or the shape-keyed grow heuristic below would pad the wrong axis
    B, S, N = 2, 10, 4
    batch = _batch(cfg, B, S, jax.random.key(3), with_targets=False)

    logits, caches = model.prefill(ctx, params, batch)

    def grow(x):
        if hasattr(x, "ndim") and x.ndim >= 3 and x.shape[-2] == S:
            pad = [(0, 0)] * x.ndim
            pad[-2] = (0, N)
            return jnp.pad(x, pad)
        return x

    caches = jax.tree.map(grow, caches)
    tok, pos, cached = _greedy(cfg, logits), S, []
    for _ in range(N):
        cached.append(np.asarray(tok))
        logits, caches = model.decode_step(ctx, params, tok,
                                           jnp.int32(pos), caches)
        assert np.isfinite(np.asarray(logits)).all()
        tok = _greedy(cfg, logits)
        pos += 1
    cached.append(np.asarray(tok))

    # reference: recompute from scratch over the growing prefix — no cache
    rt = batch["tokens"]
    for i, want in enumerate(cached):
        rb = dict(batch, tokens=rt)
        rl, _ = model.prefill(ctx, params, rb)
        got = np.asarray(_greedy(cfg, rl))
        np.testing.assert_array_equal(
            got, want, err_msg=f"{arch}: cached decode diverged at step {i}")
        nt = jnp.asarray(want)
        nt = nt[..., None] if cfg.n_codebooks > 1 else nt[:, None]
        rt = jnp.concatenate([rt, nt], axis=-1)


def test_full_configs_have_exact_assigned_dims():
    spec = {
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    }
    for arch, (L, d, H, K, ff, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, H, K, ff, V), arch


def test_family_features_present():
    assert get_config("arctic-480b").moe.n_experts == 128
    assert get_config("arctic-480b").moe.dense_residual
    assert get_config("granite-moe-3b-a800m").moe.top_k == 8
    assert get_config("minicpm3-4b").mla is not None
    assert get_config("qwen2.5-14b").qkv_bias
    assert get_config("hymba-1.5b").ssm.d_state == 16
    assert get_config("musicgen-large").n_codebooks == 4
    assert get_config("llava-next-34b").img_tokens > 0
    assert get_config("xlstm-350m").subquadratic
    assert get_config("hymba-1.5b").subquadratic
    assert not get_config("qwen2.5-14b").subquadratic


def test_param_counts_are_plausible():
    # analytic counts should land near the advertised model sizes
    expect = {"qwen2.5-14b": (12e9, 18e9), "granite-3-2b": (2e9, 4e9),
              "arctic-480b": (400e9, 520e9), "minicpm3-4b": (3e9, 6e9),
              "xlstm-350m": (0.2e9, 0.6e9), "hymba-1.5b": (1e9, 2.3e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
