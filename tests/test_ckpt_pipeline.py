"""Checkpoint pipeline subsystem: concurrent drain (batched testing, phase
deadlines, rank-id-keyed stats), the double-buffered snapshot engine, bit
identity between the pipelined and buffered paths, elastic restart with the
pipeline on, and the replicated-shard dedup subprocess scenario."""
import os
import subprocess
import sys
import threading
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CkptIOConfig
from repro.core import Cluster, ckpt_io
from repro.core.ckpt import CheckpointWriter
from repro.core.ckpt_pipeline import (HostArena, SnapshotPipeline, batch_plan,
                                      plan_snapshot)
from repro.core.drain import drain_rank, drain_world
from repro.core.restore import load_arrays, load_rank_state


# ---------------------------------------------------------------------------
# concurrent drain
# ---------------------------------------------------------------------------

def test_drain_world_stats_keyed_by_rank_id():
    c = Cluster(4, "mpich")
    c.mana(0).isend(3, tag=9, payload="x")
    stats = drain_world(c.manas)
    assert set(stats) == {0, 1, 2, 3}
    assert stats[3]["messages_buffered"] == 1
    assert all(stats[r]["messages_buffered"] == 0 for r in (0, 1, 2))


def test_drain_world_with_dead_rank_attaches_stats_to_survivors(tmp_path):
    """The PR 1 bug: stats[i] indexed a list built from ALIVE manas only, so
    with rank 1 dead, rank 2's stats landed on rank 3 (and vice versa)."""
    c = Cluster(4, "mpich", ckpt_dir=tmp_path / "ck")
    c.mana(0).isend(3, tag=5, payload="for-rank-3")
    c.kill_rank(1)
    c.checkpoint(1, {"x": jnp.zeros(2)}, None).wait()
    ck = c.writer.latest()
    rs3 = load_rank_state(ck, 3)
    rs2 = load_rank_state(ck, 2)
    assert rs3["drain"]["rank"] == 3
    assert rs3["drain"]["messages_buffered"] == 1
    assert rs2["drain"]["rank"] == 2
    assert rs2["drain"]["messages_buffered"] == 0
    c.writer.close()


def test_drain_world_parallel_path_completes_requests():
    """Force the concurrent path (a request that needs a second test round)
    and check batched completion + per-rank stats."""
    c = Cluster(3, "openmpi")
    m = c.mana(0)
    h = m.isend(1, tag=1, payload="p")
    d = m._desc(h)
    d.state["done"] = False
    flaky = {"calls": 0}
    orig = m.backend.test_all

    def test_all_flaky(reqs):
        flaky["calls"] += 1
        if flaky["calls"] == 1:          # first sweep: not done -> pool path
            return [False] * len(reqs)
        return orig(reqs)

    m.backend.test_all = test_all_flaky
    stats = drain_world(c.manas, timeout=5.0)
    assert stats[0]["requests_completed"] == 1
    assert stats[0]["test_rounds"] >= 1
    assert d.state["done"]


def test_drain_rank_request_phase_owns_half_the_budget():
    c = Cluster(2, "mpich")
    m = c.mana(0)
    m.isend(1, tag=1, payload="p")
    m._desc(m.isend(1, tag=2, payload="q"))
    for d in list(m.vids.iter_kind(__import__(
            "repro.core.descriptors", fromlist=["Kind"]).Kind.REQUEST)):
        d.state["done"] = False
    m.backend.test_all = lambda reqs: [False] * len(reqs)
    t0 = time.time()
    with pytest.raises(TimeoutError) as e:
        drain_rank(m, timeout=0.6)
    elapsed = time.time() - t0
    # phase 1 may use at most HALF the budget, leaving phase 2 its slice
    assert elapsed < 0.55, elapsed
    # the error carries the partial drain stats
    assert "partial drain" in str(e.value)
    assert "requests_completed" in str(e.value)


def test_drain_rank_fabric_phase_timeout_reports_buffered_stats():
    c = Cluster(2, "mpich")
    m = c.mana(1)
    m.backend.iprobe = lambda *a, **k: (0, 50001)
    m.backend.recv = lambda src, tag: "junk"
    with pytest.raises(TimeoutError) as e:
        drain_rank(m, timeout=0.2)
    assert "messages_buffered" in str(e.value)


@pytest.mark.parametrize("backend", ["mpich", "craympi", "openmpi", "exampi"])
def test_backend_test_all_batched(backend):
    c = Cluster(2, backend)
    m = c.mana(0)
    hs = [m.isend(1, tag=t, payload=t) for t in range(3)]
    phys = [m._desc(h).phys for h in hs]
    assert m.backend.test_all(phys) == [True, True, True]
    # Mana-level wrapper mirrors completion into descriptors
    for h in hs:
        m._desc(h).state["done"] = False
    assert m.test_all(hs) == [True, True, True]
    assert all(m._desc(h).state["done"] for h in hs)


def test_request_free_retires_vid():
    from repro.core.descriptors import Kind
    c = Cluster(2, "mpich")
    m = c.mana(0)
    h = m.isend(1, tag=1, payload="p")
    n_before = m.vids.live_count(Kind.REQUEST)
    m.request_free(h)
    assert m.vids.live_count(Kind.REQUEST) == n_before - 1
    with pytest.raises(KeyError):
        m._desc(h)


def test_pipeline_prefetch_requests_do_not_accumulate():
    """One request descriptor per *in-flight* batch, not one per consumed
    batch — consumed prefetches are freed (their growth was serialized into
    every checkpoint's blocking window)."""
    from repro.configs import smoke_config
    from repro.core.descriptors import Kind
    from repro.data import DataPipeline
    c = Cluster(1, "mpich")
    p = DataPipeline(smoke_config("granite-3-2b"), 2, 8, mana=c.mana(0))
    for _ in range(10):
        p.next()
    time.sleep(0.1)
    live = c.mana(0).vids.live_count(Kind.REQUEST)
    assert live <= 4, live      # bounded by prefetch depth, not steps
    p.stop()


# ---------------------------------------------------------------------------
# snapshot planning / batching / arenas
# ---------------------------------------------------------------------------

def test_plan_matches_legacy_snapshot_layout():
    from repro.core.ckpt import snapshot_shards
    arrays = {"a": jnp.arange(24.0).reshape(4, 6),
              "b": {"c": jnp.ones((3,), jnp.int32)}}
    leaves_meta, items = plan_snapshot(arrays, 2, None)
    legacy_meta, per_rank = snapshot_shards(arrays, 2, None)
    assert [m["shards"] for m in leaves_meta] == \
        [m["shards"] for m in legacy_meta]
    assert {it.key for it in items} == set(per_rank[0])


def test_batch_plan_rank_aligned_and_size_bounded():
    class It:
        def __init__(self, rank, nbytes):
            self.rank, self.nbytes = rank, nbytes
    items = [It(0, 60 << 10), It(1, 60 << 10), It(0, 60 << 10),
             It(0, 60 << 10), It(1, 10 << 10)]
    batches = batch_plan(items, 100 << 10)
    for rank, its in batches:
        assert all(it.rank == rank for it in its)
    # rank 0: 3x60K -> [60+60][60]; rank 1: 60+10 -> one batch
    sizes = sorted(sum(it.nbytes for it in its) >> 10 for _, its in batches)
    assert sizes == [60, 70, 120]


def test_host_arena_place_reuse_and_release():
    a = HostArena()
    assert a.try_acquire()
    assert not a.try_acquire()           # busy until released
    xs = [np.arange(10, dtype=np.float32), np.ones((3, 3), np.int8)]
    views = a.place(xs)
    for v, x in zip(views, xs):
        np.testing.assert_array_equal(v, x)
        assert v.dtype == x.dtype and v.shape == x.shape
    cap = a._buf.nbytes
    a.release()
    assert a.try_acquire()
    a.place(xs)                          # reuse: no regrowth
    assert a._buf.nbytes == cap
    a.release()


def test_snapshot_pipeline_arena_pair_cycles_across_batches():
    """More batches than arenas: the pair must CYCLE (encode tasks re-
    acquire freed arenas) — every batch lands intact and none spill."""
    pool = ckpt_io.IOPool(2)
    n = 20_000                           # 80 KB > the 64 KB min batch size
    arrays = {f"k{i}": jnp.ones((n,), jnp.float32) * i for i in range(6)}
    _, items = plan_snapshot(arrays, 1, None)
    got = {}
    lock = threading.Lock()

    def sink(rank, its, views):
        time.sleep(0.005)                # stretch arena occupancy
        with lock:
            for it, v in zip(its, views):
                got[it.key] = np.array(v)

    pipe = SnapshotPipeline(pool, batch_bytes=1)   # min-clamped: 1 item/batch
    res = pipe.run(items, sink)
    assert res["batches"] == 6
    res["release"]()
    for f in res["futures"]:
        f.result(timeout=30)
    assert res["counters"]["spills"] == 0
    for i in range(6):
        np.testing.assert_array_equal(got[f"{i}.0"], np.ones(n) * i)
    pool.close()


# ---------------------------------------------------------------------------
# pipelined writer: bit identity, delta, elastic restart, timings
# ---------------------------------------------------------------------------

def _tree():
    rng = np.random.default_rng(3)
    return {"w": jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32)),
            "z": jnp.zeros((256, 32), jnp.float32),
            "i": jnp.asarray(rng.integers(0, 99, 500).astype(np.int32)),
            "s": jnp.float32(1.5)}


@pytest.mark.parametrize("codec,incremental", [("none", False),
                                               ("zlib", True)])
def test_pipelined_bitwise_identical_to_buffered(tmp_path, codec,
                                                 incremental):
    arrays = _tree()
    digests = {}
    for name, pipe in (("buf", False), ("pipe", True)):
        w = CheckpointWriter(tmp_path / name, 2, codec=codec,
                             incremental=incremental, pipeline=pipe)
        w.checkpoint(1, arrays, None, {0: {"r": 0}, 1: {"r": 1}}).wait()
        ck = w.latest()
        out = load_arrays(ck, {k: None for k in arrays})
        for k in arrays:
            np.testing.assert_array_equal(np.asarray(out[k]),
                                          np.asarray(arrays[k]))
        digests[name] = {
            f"{r}:{k}": e["digest"]
            for r in range(2)
            for k, e in ckpt_io.read_rank_index(
                ck / f"rank{r:05d}")["entries"].items()}
        assert load_rank_state(ck, 1) == {"r": 1}
        w.close()
    assert digests["buf"] == digests["pipe"]


def test_pipelined_incremental_delta_chain(tmp_path):
    arrays = _tree()
    w = CheckpointWriter(tmp_path, 2, codec="zlib", incremental=True,
                         pipeline=True)
    st1 = w.checkpoint(1, arrays, None, {}).wait()
    assert st1["full"] and st1["bytes_written"] > 0
    # unchanged state -> zero fresh bytes
    st2 = w.checkpoint(2, arrays, None, {}).wait()
    assert not st2["full"]
    assert st2["bytes_written"] == 0 and st2["fresh_shards"] == 0
    # mutate ONE leaf -> exactly one fresh shard
    arrays["i"] = jnp.asarray(np.arange(500, dtype=np.int32))
    st3 = w.checkpoint(3, arrays, None, {}).wait()
    assert st3["fresh_shards"] == 1
    # delta restores resolve clean shards through the base step
    out = load_arrays(w.latest(), {k: None for k in arrays})
    for k in arrays:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(arrays[k]))
    w.close()


def test_pipelined_elastic_restart_world_size_change(tmp_path):
    io_cfg = CkptIOConfig(codec="zlib", incremental=True, pipeline=True)
    c = Cluster(4, "craympi", ckpt_dir=tmp_path / "ck", ckpt_io=io_cfg)
    c.checkpoint(3, {"w": jnp.arange(8.0)}, None).wait()
    fresh = c.restart(c.writer.latest(), new_world_size=2)
    assert fresh.world_size == 2
    out = load_arrays(fresh.writer.latest(), {"w": None})
    np.testing.assert_array_equal(out["w"], np.arange(8.0))
    fresh.writer.close()


def test_checkpoint_timing_breakdown(tmp_path):
    c = Cluster(2, "mpich", ckpt_dir=tmp_path / "ck")
    req = c.checkpoint(1, {"x": jnp.zeros((64, 64))}, None)
    for k in ("drain_ms", "snapshot_ms", "enqueue_ms", "blocking_ms"):
        assert k in req.timings, req.timings
    assert req.timings["blocking_ms"] >= req.timings["drain_ms"]
    req.wait()
    assert "persist_ms" in req.timings
    assert req.write_stats["arena_spills"] >= 0
    c.writer.close()


def test_pipelined_writer_error_propagates(tmp_path):
    w = CheckpointWriter(tmp_path, 1, codec="zlib", pipeline=True)
    bad = type("Bad", (), {"shape": (2,), "dtype": np.float32,
                           "nbytes": 8, "size": 2})()
    with pytest.raises(Exception):
        w.checkpoint(1, {"x": bad}, None, {}).wait()
    assert w.latest() is None           # nothing half-committed
    # the failure was DELIVERED via wait(): close() must not echo it — a
    # supervisor recovering from the failure would count the echo as a
    # second incident (Cluster.restart closes the abandoned writer)
    w.close()
    assert w._pool is None and w._inflight is None
    w.close()                           # idempotent


def test_pipelined_writer_unobserved_error_delivered_once_by_close(tmp_path):
    """A BACKGROUND failure nobody wait()ed on is still reported exactly
    once — by the first drain point (close/wait_idle) — then cleared."""
    from repro.core import faults

    w = CheckpointWriter(tmp_path, 1, codec="zlib", pipeline=True)

    def die(name, ctx):
        raise faults.InjectedFault("kill mid-append")

    faults.arm("ckpt_io.append", die)
    try:
        w.checkpoint(1, {"x": jnp.zeros(512)}, None, {})   # no wait()
        with pytest.raises(faults.InjectedFault):
            w.close()
    finally:
        faults.disarm("ckpt_io.append")
    assert w._pool is None and w._inflight is None
    w.close()                           # idempotent after delivery
    assert w.latest() is None


def test_rank_shard_writer_matches_one_shot(tmp_path):
    rng = np.random.default_rng(0)
    arrays = {"a": rng.normal(size=(100,)).astype(np.float32),
              "b": np.zeros(4096, np.int32)}
    st1 = ckpt_io.write_rank_shards(tmp_path / "one", arrays,
                                    ckpt_io.get_codec("zlib"),
                                    compute_digests=True)
    w = ckpt_io.RankShardWriter(tmp_path / "inc", ckpt_io.get_codec("zlib"))
    for k, v in arrays.items():
        w.add(k, v, compute_digest=True)
    st2 = w.finish()
    assert st1["digests"] == st2["digests"]
    assert st1["enc_bytes"] == st2["enc_bytes"]
    out = ckpt_io.read_rank_entries(tmp_path / "inc", list(arrays))
    for k in arrays:
        np.testing.assert_array_equal(out[k], arrays[k])


# ---------------------------------------------------------------------------
# replicated-shard dedup (8-device subprocess)
# ---------------------------------------------------------------------------

def test_replicated_shard_dedup_scenario():
    """A fully replicated leaf is stored exactly once and restores
    bit-identically on a different mesh shape (separate process so the
    placeholder device count never leaks into this session)."""
    script = Path(__file__).parent / "scenarios" / "replicated_scenario.py"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).parents[1] / "src")
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "REPLICATED_SCENARIO_OK" in out.stdout, out.stdout + out.stderr
