"""Unit + property tests for the new virtual-id subsystem (paper §4.2) and the
legacy baseline (§4.1)."""
import pytest
import pytest as _pytest
_pytest.importorskip("hypothesis")  # optional dep: skip, not error
from hypothesis import given, settings, strategies as st

from repro.core.descriptors import Descriptor, Kind, Strategy, comm_desc, op_desc
from repro.core.legacy_vid import LegacyVidTables
from repro.core.vid import VidTable, compute_ggid, pack_vid, vid_index, vid_kind


def test_vid_packing_roundtrip():
    for kind in Kind:
        for idx in (0, 1, 12345, (1 << 29) - 1):
            v = pack_vid(kind, idx)
            assert v < (1 << 32)
            assert vid_kind(v) == kind
            assert vid_index(v) == idx


def test_vid_packing_rejects_overflow():
    with pytest.raises(ValueError):
        pack_vid(Kind.COMM, 1 << 29)


def test_ggid_is_order_independent_and_seq_sensitive():
    assert compute_ggid([3, 1, 2], 0) == compute_ggid([1, 2, 3], 0)
    assert compute_ggid([1, 2, 3], 0) != compute_ggid([1, 2, 3], 1)


def test_same_comm_same_vid_across_ranks():
    """Two ranks creating the same logical communicator agree on the vid
    without any coordination (the ggid property MANA relies on)."""
    tables = [VidTable(), VidTable()]
    vids = [t.insert(comm_desc([0, 1, 2])) for t in tables]
    assert vids[0] == vids[1]
    # a second identical group bumps the sequence -> different vid
    v2 = tables[0].insert(comm_desc([0, 1, 2]))
    assert v2 != vids[0]


def test_two_level_table_lookup_and_free():
    t = VidTable()
    v = t.insert(op_desc("mysum"))
    assert t.lookup(v).meta["name"] == "mysum"
    t.free(v)
    with pytest.raises(KeyError):
        t.lookup(v)
    with pytest.raises(KeyError):
        t.free(v)


def test_kinds_do_not_collide():
    t = VidTable()
    a = t.insert(Descriptor(Kind.OP, meta={"name": "a"}))
    b = t.insert(Descriptor(Kind.REQUEST, meta={"op": "x"}))
    c = t.insert(Descriptor(Kind.DATATYPE, meta={"envelope": {}}))
    assert len({a, b, c}) == 3
    assert t.lookup(a).kind == Kind.OP
    assert t.lookup(b).kind == Kind.REQUEST
    assert t.lookup(c).kind == Kind.DATATYPE


def test_snapshot_excludes_physical_handles():
    t = VidTable()
    d = op_desc("s")
    d_vid = t.insert(d)
    d.phys = object()   # lower-half pointer
    snap = t.snapshot()
    t2 = VidTable.restore(snap)
    assert t2.lookup(d_vid).phys is None          # never serialized
    assert t2.lookup(d_vid).meta["name"] == "s"


def test_reverse_lookup():
    t = VidTable()
    d = op_desc("x")
    v = t.insert(d)
    d.phys = 1234
    assert t.reverse(Kind.OP, 1234) == v
    assert t.reverse(Kind.OP, 999) is None


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from(list(Kind)), min_size=1, max_size=60))
def test_insert_lookup_invariant(kinds):
    t = VidTable()
    vids = []
    for i, k in enumerate(kinds):
        d = Descriptor(k, meta={"ranks": [0, i], "i": i} if k in
                       (Kind.COMM, Kind.GROUP) else {"i": i})
        vids.append((t.insert(d), i))
    assert len({v for v, _ in vids}) == len(vids)       # all unique
    for v, i in vids:
        assert t.lookup(v).meta["i"] == i               # content preserved
    assert t.live_count() == len(vids)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.integers(0, 31), min_size=1, max_size=8,
                         unique=True), min_size=1, max_size=20))
def test_ggid_agreement_property(groups):
    """N independent tables creating the same comm sequence assign identical
    vids — the distributed-agreement property."""
    t1, t2 = VidTable(), VidTable()
    for ranks in groups:
        assert t1.insert(comm_desc(ranks)) == t2.insert(comm_desc(ranks))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["MPI_Comm", "MPI_Op"]),
                          st.integers(0, 1 << 30)), min_size=1, max_size=40))
def test_legacy_tables_equivalent_semantics(items):
    lt = LegacyVidTables()
    vids = [(kind, lt.insert(kind, phys), phys) for kind, phys in items]
    for kind, v, phys in vids:
        assert lt.virtual_to_real(kind, v) == phys
    # reverse lookup returns *a* vid bound to that phys value
    kind, v, phys = vids[0]
    rv = lt.real_to_virtual(kind, phys)
    assert lt.virtual_to_real(kind, rv) == phys


def test_snapshot_roundtrip_preserves_all_descriptors():
    t = VidTable()
    vs = [t.insert(comm_desc([0, 1], color=1, key=2)),
          t.insert(op_desc("x")),
          t.insert(Descriptor(Kind.DATATYPE,
                              meta={"envelope": {"combiner": "vector"}},
                              strategy=Strategy.SERIALIZE))]
    t2 = VidTable.restore(t.snapshot())
    for v in vs:
        a, b = t.lookup(v), t2.lookup(v)
        assert a.kind == b.kind and a.strategy == b.strategy
        assert b.vid == v
