"""End-to-end system behaviour: training convergence, transparent checkpoint/
restart determinism, failure injection + cross-backend failover, serving
snapshots, and the 8-device elastic scenario (subprocess)."""
import os
import subprocess
import sys
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch.train import Trainer

TINY = replace(smoke_config("granite-3-2b"), n_layers=2, d_model=64,
               n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
               vocab_size=256, vocab_pad_multiple=64)


def make_trainer(tmp, **kw):
    kw.setdefault("batch_size", 4)
    kw.setdefault("seq_len", 16)
    kw.setdefault("world_size", 2)
    kw.setdefault("ckpt_dir", tmp)
    kw.setdefault("total_steps", 100)
    return Trainer(TINY, mesh=None, **kw)


def test_training_loss_decreases(tmp_path):
    tr = make_trainer(tmp_path / "ck")
    tr.init_state()
    tr.run(60, log_every=10)
    tr.pipeline.stop()
    assert tr.history[-1]["loss"] < tr.history[0]["loss"] - 0.3


def test_checkpoint_restart_is_deterministic(tmp_path):
    """Train 30; separately train 20, ckpt, restore, train 10 — identical."""
    a = make_trainer(tmp_path / "a", backend="mpich")
    a.init_state()
    a.run(30, log_every=30)
    a.pipeline.stop()

    b = make_trainer(tmp_path / "b", backend="mpich")
    b.init_state()
    b.run(20, log_every=20)
    b.checkpoint().wait()
    b.pipeline.stop()
    c = make_trainer(tmp_path / "b", backend="mpich")
    c._build_step()
    c.restore(b.cluster.writer.latest())
    assert c.step == 20
    c.run(10, log_every=10)
    c.pipeline.stop()
    assert c.history[-1]["loss"] == pytest.approx(a.history[-1]["loss"],
                                                  rel=1e-6)


def test_failure_injection_and_cross_backend_failover(tmp_path):
    tr = make_trainer(tmp_path / "ck", backend="craympi")
    tr.init_state()
    tr.run(30, ckpt_every=10, kill_rank_at=25,
           new_backend_on_restart="exampi", log_every=10)
    tr.pipeline.stop()
    assert tr.cluster.backend_name == "exampi"
    assert tr.cluster.restart_count == 1
    kinds = [e[0] for e in tr.cluster.events]
    assert "restarted" in kinds
    # made it back to (at least) the target step
    assert tr.step == 30


def test_failure_detection_by_heartbeat(tmp_path):
    tr = make_trainer(tmp_path / "ck")
    tr.init_state()
    tr.cluster.ranks[1].last_heartbeat -= 100.0
    dead = tr.cluster.detect_failures(timeout_s=5.0)
    assert dead == [1]
    assert not tr.cluster.ranks[1].alive
    tr.pipeline.stop()


def test_serving_snapshot_roundtrip(tmp_path):
    from repro.serving.engine import Server
    cfg = TINY
    srv = Server(cfg, ckpt_dir=tmp_path / "sck")
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8),
                                                dtype=np.int32)
    logits = srv.prefill(prompts, pad_to=16)
    first = np.argmax(np.asarray(logits)[..., :cfg.vocab_size], -1).astype(np.int32)
    a_toks, _ = srv.decode(3, first)
    srv.checkpoint(tag=1).wait()
    b_toks, _ = srv.decode(2, a_toks[-1])

    # a second server restores mid-generation and must produce the same tokens
    srv2 = Server(cfg, ckpt_dir=tmp_path / "sck")
    srv2.prefill(prompts, pad_to=16)  # builds cache structure
    srv2.restore(srv.cluster.writer.latest())
    assert srv2.pos == srv.pos - 2
    c_toks, _ = srv2.decode(2, a_toks[-1])
    np.testing.assert_array_equal(b_toks[0], c_toks[0])
    np.testing.assert_array_equal(b_toks[1], c_toks[1])


@pytest.mark.slow
def test_elastic_scenario_8_devices():
    """Full elastic restart on an 8-device fleet (separate process so the
    placeholder device count never leaks into this test session)."""
    script = Path(__file__).parent / "scenarios" / "elastic_scenario.py"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).parents[1] / "src")
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=560)
    assert "ELASTIC_SCENARIO_OK" in out.stdout, out.stdout + out.stderr


def test_chaos_matrix_quick():
    """The chaos harness itself (sweep driver, injector wiring, byte-
    identical assertion) on two cells; the full fault-type sweep runs as
    the CI `chaos` job (`chaos_matrix.py --smoke`)."""
    script = Path(__file__).parent / "scenarios" / "chaos_matrix.py"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).parents[1] / "src")
    out = subprocess.run([sys.executable, str(script), "--quick"], env=env,
                         capture_output=True, text=True, timeout=560)
    assert "CHAOS_MATRIX_OK" in out.stdout, out.stdout + out.stderr


def test_serve_restore_rewinds_generated_stream(tmp_path):
    """Rewinding pos at restore must also truncate Server.generated — the
    tokens decoded between snapshot and failure would otherwise appear
    twice after the supervisor replays them."""
    from repro.serving.engine import Server
    cfg = smoke_config("granite-3-2b")
    srv = Server(cfg, ckpt_dir=tmp_path / "g")
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8), dtype=np.int32)
    logits = srv.prefill(prompts, pad_to=16)
    first = np.argmax(np.asarray(logits)[..., : cfg.vocab_size],
                      axis=-1).astype(np.int32)
    toks, _ = srv.decode(3, first)
    srv.checkpoint().wait()
    srv.decode(2, toks[-1])                 # progress that will be lost
    assert len(srv.generated) == 5
    srv.restore(srv.cluster.writer.latest(), rebuild=True)
    assert srv.pos == 8 + 3
    assert len(srv.generated) == 3          # replayed tokens not duplicated
    srv.decode(2, srv.resume_tok)
    assert len(srv.generated) == 5
