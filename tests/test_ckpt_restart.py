"""Checkpoint/restart integration: per-rank images, atomic commit, async
writer, object re-binding across backend flavors, array roundtrips."""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Cluster
from repro.core.restore import load_arrays, load_manifest, load_rank_state


def split_all(cluster, color_fn):
    out = [None] * cluster.world_size

    def run(r):
        m = cluster.mana(r)
        out[r] = m.comm_split(m.comm_world(), color_fn(r), r)

    ts = [threading.Thread(target=run, args=(r,))
          for r in range(cluster.world_size)]
    [t.start() for t in ts]
    [t.join(timeout=30) for t in ts]
    return out


@pytest.fixture
def cluster(tmp_path):
    return Cluster(4, "craympi", ckpt_dir=tmp_path / "ck")


def test_array_roundtrip(cluster):
    arrays = {"a": jnp.arange(24.0).reshape(4, 6),
              "b": {"c": jnp.ones((3,), jnp.int32)}}
    req = cluster.checkpoint(1, arrays, None)
    st = req.wait()
    assert st["bytes_total"] > 0
    ck = cluster.writer.latest()
    out = load_arrays(ck, jax.tree.map(lambda x: None, arrays))
    np.testing.assert_array_equal(out["a"], arrays["a"])
    np.testing.assert_array_equal(out["b"]["c"], arrays["b"]["c"])


def test_atomic_commit_and_gc(cluster):
    arrays = {"x": jnp.zeros((2,))}
    for step in (1, 2, 3, 4, 5):
        cluster.checkpoint(step, arrays, None).wait()
    done = sorted(p.name for p in cluster.writer.base.iterdir())
    assert "step_00000005" in done[-1]
    # keep=3 garbage collection
    commits = [p for p in cluster.writer.base.iterdir()
               if (p / "COMMIT").exists()]
    assert len(commits) == 3
    # no half-written tmp dirs remain
    assert not any(p.name.endswith(".tmp") for p in cluster.writer.base.iterdir())


def test_manifest_records_stragglers(cluster):
    arrays = {"x": jnp.zeros((128, 128))}
    cluster.checkpoint(7, arrays, None).wait()
    man = load_manifest(cluster.writer.latest())
    assert man["world_size"] == 4
    assert "straggler_rank" in man and "per_rank_write_s" in man
    assert man["bytes_total"] >= 128 * 128 * 4


@pytest.mark.parametrize("new_backend", ["mpich", "openmpi", "exampi"])
def test_cross_backend_restart_rebinds_everything(cluster, new_backend):
    """Checkpoint under Cray MPI, restart under another implementation — with
    NON-primitive MPI objects (what [GPC19 §3.6] could not do, paper §9)."""
    subs = split_all(cluster, lambda r: r % 2)
    m0 = cluster.mana(0)
    t = m0.type_vector(3, 2, 8, m0.dtype_handles["MPI_INT32_T"])
    cluster.mana(3).isend(0, tag=11, payload={"inflight": True})
    cluster.checkpoint(2, {"w": jnp.ones((4, 4))}, None).wait()

    fresh = cluster.restart(cluster.writer.latest(), new_backend=new_backend)
    f0 = fresh.mana(0)
    # the OLD handle values (stored anywhere in app state) still work
    assert f0.comm_size(subs[0]) == 2
    env = f0.type_envelope(t)
    assert env["combiner"] == "vector" and env["stride"] == 8
    # drained in-flight message redelivered exactly once
    assert f0.recv(3, 11) == {"inflight": True}
    with pytest.raises(Exception):
        f0.recv(3, 11)
    # physical handles belong to the NEW flavor
    if new_backend == "exampi":
        from repro.core.backends.exampi import SharedPtr
        assert isinstance(f0._phys(subs[0]), SharedPtr)
    if new_backend == "mpich":
        assert isinstance(f0._phys(subs[0]), int)


def test_elastic_restart_world_size_change(cluster):
    split_all(cluster, lambda r: r % 2)
    cluster.checkpoint(3, {"w": jnp.arange(8.0)}, None).wait()
    fresh = cluster.restart(cluster.writer.latest(), new_world_size=2)
    assert fresh.world_size == 2
    assert fresh.mana(0).vids.live_count() > 0
    out = load_arrays(fresh.writer.latest(), {"w": None})
    np.testing.assert_array_equal(out["w"], np.arange(8.0))


def test_rank_state_contains_mana_snapshot(cluster):
    cluster.checkpoint(4, {"x": jnp.zeros(1)}, None).wait()
    rs = load_rank_state(cluster.writer.latest(), 2)
    assert rs["mana"]["backend_name"] == "craympi"
    assert "descriptors" in rs["mana"]["vids"]
    # physical handles never serialized
    blob = json.dumps(rs)
    assert "_cray_ofi_ep" not in blob


def test_checkpoint_drains_first(cluster):
    cluster.mana(1).isend(2, tag=5, payload="pending")
    cluster.checkpoint(5, {"x": jnp.zeros(1)}, None).wait()
    assert cluster.fabric.pending_count(2) == 0
    rs = load_rank_state(cluster.writer.latest(), 2)
    assert len(rs["mana"]["pending"]) == 1


# ---------------------------------------------------------------------------
# RNG-stream / loss-trajectory determinism across resume
# ---------------------------------------------------------------------------

def _tiny_trainer(ckpt_dir, backend):
    from dataclasses import replace

    from repro.configs import CkptIOConfig, smoke_config
    from repro.launch.train import Trainer
    cfg = replace(smoke_config("granite-3-2b"), n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256, vocab_pad_multiple=64)
    return Trainer(cfg, batch_size=2, seq_len=8, world_size=2,
                   backend=backend, ckpt_dir=ckpt_dir, total_steps=32,
                   ckpt_io=CkptIOConfig(codec="zlib", incremental=True))


@pytest.mark.slow
@pytest.mark.parametrize("dst", ["craympi", "fabric"],
                         ids=["same-flavor", "cross-family"])
def test_resume_is_trajectory_deterministic(tmp_path, dst):
    """Resume-from-checkpoint at step k must replay the SAME loss
    trajectory as an uninterrupted run for >= 5 further steps — the data
    cursor and RNG stream are runtime state, restored bit-exactly whether
    the restart stays on the same flavor or crosses families."""
    k, extra = 3, 6
    ref = _tiny_trainer(tmp_path / "ref", "craympi")
    ref.init_state()
    try:
        ref_losses = [float(ref.step_once()["loss"])
                      for _ in range(k + extra)]
        ref_key = np.asarray(jax.random.key_data(ref.rng_key))
    finally:
        ref.pipeline.stop()
        ref.cluster.writer.close()

    tr = _tiny_trainer(tmp_path / "run", "craympi")
    tr.init_state()
    try:
        head = [float(tr.step_once()["loss"]) for _ in range(k)]
        assert head == ref_losses[:k]
        tr.checkpoint().wait()
    finally:
        tr.pipeline.stop()
        tr.cluster.writer.close()

    # a FRESH process resumes the checkpoint, possibly on another flavor
    tr2 = _tiny_trainer(tmp_path / "run", dst)
    tr2.init_state()
    try:
        ck = tr2.resume_latest(new_backend=dst)
        assert ck is not None and tr2.step == k
        assert tr2.cluster.backend_name == dst
        tail = [float(tr2.step_once()["loss"]) for _ in range(extra)]
        assert tail == ref_losses[k:], \
            f"resumed trajectory diverged on {dst}"
        assert np.asarray(jax.random.key_data(tr2.rng_key)).tobytes() == \
            ref_key.tobytes(), "RNG stream diverged after resume"
    finally:
        tr2.pipeline.stop()
        tr2.cluster.writer.close()
