"""Cross-backend elastic restart: the full save->restore backend-pair
matrix (docs/restart_matrix.md) at world=4.

Every ordered (checkpoint_backend, restart_backend) pair is exercised
against one rich checkpoint per source flavor — split communicators, a
derived datatype over an ALIASED base (MPI_INT8_T), a custom reduction op,
an in-flight message drained into the image — asserting restored
param/optimizer equality, live handle translation through OLD handle
values, drain-log replay stats, and the capability-translation counters
the pair plan predicts."""
import itertools
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BACKENDS, Cluster, backend_family, restart_matrix
from repro.core.restore import (find_resumable, load_arrays, load_rank_state,
                                translation_plan)

# the full ordered-pair sweep at world=4 is the heavyweight tier-1 tail;
# CI runs it in the dedicated slow step
pytestmark = pytest.mark.slow

WORLD = 4
PAIRS = sorted(itertools.product(BACKENDS, BACKENDS))


def _split_all(cluster, color_fn):
    out = [None] * cluster.world_size

    def run(r):
        m = cluster.mana(r)
        out[r] = m.comm_split(m.comm_world(), color_fn(r), r)

    ts = [threading.Thread(target=run, args=(r,))
          for r in range(cluster.world_size)]
    [t.start() for t in ts]
    [t.join(timeout=30) for t in ts]
    assert all(h is not None for h in out)
    return out


def _coll(cluster, fn, ranks=None):
    """Drive a collective wrapper on each selected rank's own thread."""
    ranks = list(range(cluster.world_size)) if ranks is None else ranks
    out, errs = {}, []

    def run(r):
        try:
            out[r] = fn(cluster.mana(r))
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=run, args=(r,)) for r in ranks]
    [t.start() for t in ts]
    [t.join(timeout=30) for t in ts]
    if errs:
        raise errs[0]
    return [out[r] for r in ranks]


class _SrcCkpt:
    """One source flavor's checkpoint plus the OLD handle values the
    restarted side must keep honoring."""

    def __init__(self, base_dir, src: str):
        rng = np.random.default_rng(7)
        self.arrays = {
            "params": jnp.asarray(rng.normal(size=(32, 16))
                                  .astype(np.float32)),
            "opt": {"m": jnp.asarray(rng.normal(size=(32, 16))
                                     .astype(np.float32)),
                    "count": jnp.asarray(np.int32(13))},
        }
        self.shardings = jax.tree.map(lambda _: None, self.arrays)
        self.cluster = Cluster(WORLD, src, ckpt_dir=base_dir / f"ck_{src}")
        self.subs = _split_all(self.cluster, lambda r: r % 2)
        m0 = self.cluster.mana(0)
        self.vec = m0.type_vector(3, 2, 8, m0.dtype_handles["MPI_INT8_T"])
        self.op = m0.op_create("logsumexp", commutative=False)
        self.cluster.mana(3).isend(0, tag=21, payload={"src": src})
        # collective-using workload: a completed world allreduce (native or
        # derived per flavor) plus a scatter left IN FLIGHT — root entered,
        # peers not yet — whose fan-out the quiesce must drain into the
        # image (scatter is root->each-member under every flavor, so the
        # drained pattern completes under any restart flavor of the matrix)
        self.allred = _coll(self.cluster,
                            lambda m: m.allreduce(m.comm_world(), m.rank + 1,
                                                  m.op_handles["MPI_SUM"]))
        assert self.allred == [10] * WORLD
        m2 = self.cluster.mana(2)
        m2.scatter(m2.comm_world(),
                   [{"src": src, "chunk": q} for q in range(WORLD)], root=2)
        self.cluster.checkpoint(5, self.arrays, None).wait()
        self.ck = self.cluster.writer.latest()


@pytest.fixture(scope="module")
def src_ckpts(tmp_path_factory):
    base = tmp_path_factory.mktemp("matrix")
    return {src: _SrcCkpt(base, src) for src in BACKENDS}


@pytest.mark.parametrize("src,dst", PAIRS)
def test_backend_pair_restart(src_ckpts, src, dst):
    sc = src_ckpts[src]
    fresh = sc.cluster.restart(sc.ck, new_backend=dst,
                               shardings=sc.shardings)
    # -- param/optimizer equality through the overlapped restore ----------
    got = fresh.restored_arrays
    np.testing.assert_array_equal(np.asarray(got["params"]),
                                  np.asarray(sc.arrays["params"]))
    np.testing.assert_array_equal(np.asarray(got["opt"]["m"]),
                                  np.asarray(sc.arrays["opt"]["m"]))
    assert int(got["opt"]["count"]) == 13
    # -- old handle values stay live under the new flavor ------------------
    f0 = fresh.mana(0)
    assert f0.comm_size(sc.subs[0]) == WORLD // 2
    env = f0.type_envelope(sc.vec)
    assert env["combiner"] == "vector" and env["stride"] == 8
    base_name = env["base"]["name"]
    # envelope re-encode: the aliased base landed on dst's canonical name
    # (the SOURCE may itself have canonicalized at creation time — exampi
    # resolves MPI_INT8_T to the shared MPI_CHAR pointer before logging)
    plan = translation_plan(src, dst, f0.backend)
    src_canonical = translation_plan(src, src).dtype_aliases["MPI_INT8_T"]
    assert base_name == plan.dtype_aliases.get(src_canonical, src_canonical)
    # -- drained in-flight message redelivered exactly once ----------------
    assert f0.recv(3, 21) == {"src": src}
    # nothing left, buffered or on the fabric (iprobe: non-blocking)
    assert f0.iprobe(3, 21) is None
    # -- the in-flight scatter completes from the drained image ------------
    for r in (0, 1, 3):
        m = fresh.mana(r)
        assert m.scatter(m.comm_world(), None, root=2) \
            == {"src": src, "chunk": r}, f"{src}->{dst}: scatter replay"
    # -- fresh collectives run under the NEW flavor over restored handles --
    got = _coll(fresh, lambda m: m.allreduce(m.comm_world(), m.rank * 2,
                                             m.op_handles["MPI_SUM"]))
    assert got == [12] * WORLD
    # ... including on a restored SPLIT communicator ({0, 2})
    sub_sum = _coll(fresh, lambda m: m.allreduce(sc.subs[0], m.rank + 1,
                                                 m.op_handles["MPI_SUM"]),
                    ranks=[0, 2])
    assert sub_sum == [4, 4]
    # -- drain-log replay stats rode the checkpoint image ------------------
    rs = load_rank_state(sc.ck, 0)
    assert rs["drain"]["messages_buffered"] >= 1 \
        or load_rank_state(sc.ck, 3)["drain"]["requests_completed"] >= 1
    # -- rebind counters match what the pair plan predicts -----------------
    st = fresh.rebind_stats[0]
    assert st["pair"] == f"{src}->{dst}"
    assert st["lazy"] >= 3          # world comm + named dtypes + ops
    if plan.replay_comm_split:
        assert st["replayed"] >= 2  # the split comm AND the custom op
    else:
        assert st["serialized"] >= 1
        assert st["replayed"] >= 1  # ops always replay
    # -- restart timings mirror checkpoint's phase breakdown ---------------
    for key in ("manifest_ms", "lower_half_ms", "rebind_ms", "arrays_ms",
                "total_ms"):
        assert key in fresh.restart_timings


def test_matrix_shape_and_families():
    m = restart_matrix()
    assert len(m) == len(BACKENDS) ** 2
    for (s, d), plan in m.items():
        assert plan.same_family == (backend_family(s) == backend_family(d))
    # the MPICH family replays across its members; nobody else cross-replays
    assert m[("craympi", "mpich")].replay_comm_split
    assert m[("mpich", "craympi")].replay_comm_split
    assert not m[("mpich", "openmpi")].replay_comm_split
    assert not m[("openmpi", "exampi")].replay_comm_split
    # exampi restarts re-encode aliased dtype envelopes; others don't
    assert m[("mpich", "exampi")].reencode_envelopes
    assert not m[("exampi", "mpich")].reencode_envelopes


def test_parallel_rebind_matches_sequential(src_ckpts):
    sc = src_ckpts["craympi"]
    par = sc.cluster.restart(sc.ck, new_backend="openmpi",
                             shardings=sc.shardings, parallel=True)
    seq = sc.cluster.restart(sc.ck, new_backend="openmpi",
                             shardings=sc.shardings, parallel=False)
    np.testing.assert_array_equal(np.asarray(par.restored_arrays["params"]),
                                  np.asarray(seq.restored_arrays["params"]))
    for a, b in zip(par.rebind_stats, seq.rebind_stats):
        assert {k: a[k] for k in ("replayed", "serialized", "lazy")} \
            == {k: b[k] for k in ("replayed", "serialized", "lazy")}
    assert par.mana(0).comm_size(sc.subs[0]) \
        == seq.mana(0).comm_size(sc.subs[0])


@pytest.mark.parametrize("new_world", [2, 6])
def test_elastic_world_resize_across_backends(src_ckpts, new_world):
    sc = src_ckpts["mpich"]
    fresh = sc.cluster.restart(sc.ck, new_backend="fabric",
                               new_world_size=new_world,
                               shardings=sc.shardings)
    assert fresh.world_size == new_world
    assert len(fresh.rebind_stats) == new_world
    np.testing.assert_array_equal(np.asarray(fresh.restored_arrays["params"]),
                                  np.asarray(sc.arrays["params"]))
    # rank images wrap around: every new rank has a live vid table
    for r in range(new_world):
        assert fresh.mana(r).vids.live_count() > 0


def test_find_resumable_skips_orphaned_delta_chain(tmp_path):
    import shutil

    from repro.core.ckpt import CheckpointWriter

    # keep=5: GC retains everything here AND deltas stay deltas (a full
    # checkpoint only every 5th) — keep=0 would force every step full
    w = CheckpointWriter(tmp_path, 2, keep=5, codec="none",
                         incremental=True)
    arrays = {"w": jnp.arange(8.0)}
    try:
        w.checkpoint(1, arrays, None, {}).wait()      # full
        w.checkpoint(2, arrays, None, {}).wait()      # delta on 1
        w.checkpoint(3, arrays, None, {}).wait()      # delta on 1
    finally:
        w.close()
    assert find_resumable(tmp_path).name == "step_00000003"
    # orphan the chain: the base full checkpoint disappears behind GC's back
    shutil.rmtree(tmp_path / "step_00000001")
    res = find_resumable(tmp_path)
    # steps 2 and 3 reference step 1 -> unusable; nothing intact remains
    assert res is None
    # a later FULL checkpoint becomes resumable again
    w2 = CheckpointWriter(tmp_path, 2, keep=5, codec="none",
                          incremental=True)
    try:
        w2.checkpoint(4, arrays, None, {}).wait()
    finally:
        w2.close()
    assert find_resumable(tmp_path).name == "step_00000004"
    out = load_arrays(tmp_path / "step_00000004", {"w": None})
    np.testing.assert_array_equal(out["w"], np.arange(8.0))


def test_nested_split_replay_keeps_parent_dependency(tmp_path):
    """A replayed split must bind AFTER its parent regardless of ggid hash
    order (vids are CRC32 of member ranks — a child can hash below its
    parent, which a single-pass planner would mis-order)."""
    from repro.core import Fabric, Mana
    from repro.core.descriptors import Kind
    from repro.core.restore import _plan_rebind, rebind_objects
    from repro.core import ckpt_io

    c = Cluster(WORLD, "mpich", ckpt_dir=tmp_path / "ck")
    subs = _split_all(c, lambda r: r % 2)      # world -> {0,2} / {1,3}
    # split the SUBCOMM again: a replayable split whose parent is itself
    # a replayed descriptor
    nested = [None] * WORLD

    def run(r):
        m = c.mana(r)
        nested[r] = m.comm_split(subs[r], color=0, key=r)

    ts = [threading.Thread(target=run, args=(r,)) for r in range(WORLD)]
    [t.start() for t in ts]
    [t.join(timeout=30) for t in ts]
    snap = c.mana(0).snapshot()

    # the plan must carry a dep edge for EVERY replayed child whose parent
    # is rebuilt in this pass, independent of hash order
    shell = Mana("craympi", Fabric(WORLD), 0, WORLD)
    rp = _plan_rebind(shell, snap)
    replayed_children = [
        vid for vid, mode in rp.modes.items()
        if mode == "replay" and rp.by_vid[vid].kind == Kind.COMM
        and rp.by_vid[vid].meta.get("parent") in rp.modes
        and rp.modes[rp.by_vid[vid].meta.get("parent")] != "lazy"]
    assert replayed_children, "scenario must produce a dependent split"
    for vid in replayed_children:
        assert vid in rp.deps, f"missing parent dep for {vid:#x}"

    # end-to-end under the PARALLEL engine: nested membership survives
    pool = ckpt_io.IOPool(4)
    try:
        m2 = Mana("craympi", Fabric(WORLD), 0, WORLD)
        rebind_objects(m2, c.mana(0).snapshot(), pool=pool)
    finally:
        pool.close()
    assert m2.comm_size(nested[0]) == 2
    assert sorted(m2._desc(nested[0]).meta["ranks"]) == [0, 2]
    phys = m2._phys(nested[0])
    assert sorted(m2.backend.comm_ranks(phys)) == [0, 2]


def test_mana_restore_single_rank_api(src_ckpts):
    """Mana.restore stays the supported single-rank entry point (used
    outside Cluster.restart), with and without a pool."""
    from repro.core import Fabric, Mana
    from repro.core import ckpt_io

    sc = src_ckpts["openmpi"]
    snap = load_rank_state(sc.ck, 0)["mana"]
    fabric = Fabric(WORLD)
    seq = Mana.restore(dict(snap), fabric, 0, WORLD, backend_name="mpich")
    assert seq.comm_size(sc.subs[0]) == WORLD // 2
    pool = ckpt_io.IOPool(2)
    try:
        snap2 = load_rank_state(sc.ck, 0)["mana"]
        par = Mana.restore(snap2, Fabric(WORLD), 0, WORLD,
                           backend_name="exampi", pool=pool)
    finally:
        pool.close()
    assert par.comm_size(sc.subs[0]) == WORLD // 2
    assert par.type_envelope(sc.vec)["combiner"] == "vector"


def test_resumable_writer_accessor(tmp_path):
    from repro.core.ckpt import CheckpointWriter

    w = CheckpointWriter(tmp_path, 2)
    try:
        assert w.resumable() is None
        w.checkpoint(9, {"x": jnp.zeros(3)}, None, {}).wait()
        assert w.resumable() == w.latest()
    finally:
        w.close()


# ---------------------------------------------------------------------------
# chaos fallback: torn/corrupted newest checkpoint, restore on every family
# ---------------------------------------------------------------------------

def test_corrupt_and_torn_ckpts_fall_back_on_all_families(tmp_path):
    """Corrupt one shard of the newest committed checkpoint AND leave a
    kill-mid-append half-written step behind it: digest-verified resumable
    selection must land on the last complete, digest-valid checkpoint, and
    that checkpoint must restore under EVERY backend family."""
    from repro.configs import CkptIOConfig
    from repro.core import ckpt_io, faults
    from repro.core.restore import verify_checkpoint

    rng = np.random.default_rng(11)
    arrays1 = {"w": jnp.asarray(rng.normal(size=(48, 8)).astype(np.float32))}
    arrays2 = {"w": jnp.asarray(rng.normal(size=(48, 8)).astype(np.float32))}
    base = tmp_path / "ck"
    c = Cluster(2, "mpich", ckpt_dir=base,
                ckpt_io=CkptIOConfig(codec="zlib", incremental=True))
    c.checkpoint(1, arrays1, None).wait()
    good = c.writer.latest()
    c.checkpoint(2, arrays2, None).wait()

    # corrupt one shard of the newest COMMITTED image
    newest = c.writer.latest()
    assert newest != good
    binf = newest / "rank00000" / ckpt_io.BIN_NAME
    data = bytearray(binf.read_bytes())
    data[len(data) // 2] ^= 0x5A
    binf.write_bytes(bytes(data))
    assert verify_checkpoint(newest), "corruption escaped verification"

    # and a kill-mid-append on top: step 3 dies half-written (uncommitted)
    def die(name, ctx):
        raise faults.InjectedFault("kill mid-append")

    faults.arm("ckpt_io.append", die)
    try:
        with pytest.raises(Exception):
            c.checkpoint(3, arrays1, None).wait()
    finally:
        faults.disarm("ckpt_io.append")

    assert find_resumable(base) == good
    # the surviving checkpoint restores under every implementation family
    families = {}
    for name in BACKENDS:
        families.setdefault(backend_family(name), name)
    for fam, dst in sorted(families.items()):
        fresh = c.restart(good, new_backend=dst,
                          shardings={"w": None})
        got = np.asarray(fresh.restored_arrays["w"])
        np.testing.assert_array_equal(got, np.asarray(arrays1["w"]),
                                      err_msg=f"family {fam} ({dst})")
        assert fresh.backend_name == dst
        fresh.writer.close()
    c.writer.close()
