"""Backend contract tests: the paper's §3 design-choice matrix must actually
differ between flavors, while the §5 core subset behaves identically."""
import pytest

from repro.core.backends import BACKENDS, Fabric, make_backend
from repro.core.backends.exampi import SharedPtr

ALL = list(BACKENDS)


@pytest.mark.parametrize("name", ALL)
def test_core_subset_contract(name):
    f = Fabric(2)
    b = make_backend(name, f, 0, 2)
    c = b.comm_create([0, 1])
    assert b.comm_ranks(c) == [0, 1]
    g = b.comm_group(c)
    assert b.group_translate_ranks(g) == [0, 1]
    t = b.type_create({"combiner": "contiguous", "count": 3})
    assert b.type_get_envelope(t)["count"] == 3
    r = b.isend(1, 5, "hello")
    assert b.test(r) is True
    assert f.recv(1, 0, 5) == "hello"
    b.comm_free(c)
    with pytest.raises((KeyError, TypeError)):
        b.comm_ranks(c)


def test_mpich_constants_stable_across_sessions():
    f = Fabric(2)
    b1 = make_backend("mpich", f, 0, 2)
    b2 = make_backend("mpich", Fabric(2), 0, 2)
    assert b1.world_comm() == b2.world_comm()               # fixed ints
    assert b1.predefined_dtype("MPI_FLOAT") == b2.predefined_dtype("MPI_FLOAT")
    assert isinstance(b1.world_comm(), int)
    assert (b1.world_comm() >> 24) == 0x44                  # MPICH kind prefix


def test_openmpi_constants_differ_across_sessions():
    """Open MPI constants are function results — pointers differ per session
    (paper §4.3); MANA must not bake them in."""
    b1 = make_backend("openmpi", Fabric(2), 0, 2)
    b2 = make_backend("openmpi", Fabric(2), 0, 2)
    assert b1.world_comm() != b2.world_comm()
    assert b1.predefined_dtype("MPI_FLOAT") != b2.predefined_dtype("MPI_FLOAT")


def test_exampi_lazy_constants_and_aliasing():
    b = make_backend("exampi", Fabric(2), 0, 2)
    assert b._world is None                    # nothing resolved at startup
    w = b.world_comm()
    assert isinstance(w, SharedPtr)
    assert b.world_comm() is w                 # resolved once, cached
    # INT8_T and CHAR share a pointer (reinterpret-cast aliasing)
    assert b.predefined_dtype("MPI_INT8_T") is b.predefined_dtype("MPI_CHAR")


def test_exampi_subset_has_no_comm_split():
    b = make_backend("exampi", Fabric(2), 0, 2)
    assert "comm_split" not in b.capabilities()
    with pytest.raises(NotImplementedError):
        b.comm_split(b.world_comm(), 0, 0, [0])


def test_craympi_is_mpich_family_with_vendor_fields():
    b = make_backend("craympi", Fabric(2), 0, 2)
    c = b.comm_create([0, 1])
    st = b._deref("comm", c)
    assert "_cray_nic" in st and "_cray_ofi_ep" in st       # vendor-private
    # handle encoding is the MPICH one
    assert (c >> 24) == 0x44


@pytest.mark.parametrize("name", ALL)
def test_handle_types_differ_but_decode_agrees(name):
    """Whatever the physical representation, the decoded envelope (the §5
    category-2 functions) is identical — this is what reconstruction uses."""
    b = make_backend(name, Fabric(1), 0, 1)
    env = {"combiner": "vector", "count": 2, "blocklength": 3, "stride": 4}
    t = b.type_create(env)
    got = b.type_get_envelope(t)
    assert {k: got[k] for k in env} == env


def test_fabric_fifo_per_channel():
    f = Fabric(2)
    for i in range(5):
        f.send(0, 1, 9, i)
    assert [f.recv(1, 0, 9) for _ in range(5)] == list(range(5))


def test_fabric_iprobe_wildcards():
    f = Fabric(3)
    assert f.iprobe(2) is None
    f.send(0, 2, 4, "x")
    assert f.iprobe(2) == (0, 4)
    assert f.iprobe(2, src=1) is None
    assert f.iprobe(2, src=0, tag=4) == (0, 4)
