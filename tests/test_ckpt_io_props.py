"""Property-based round-trip coverage for the ckpt_io codec layer over
adversarial runtime-state payloads: 0-d leaves, bf16/float8 dtypes, empty
caches, and multi-chunk entries — byte-identity and digest stability must
hold across every lossless codec."""
import tempfile
from pathlib import Path

import numpy as np
import pytest as _pytest

_pytest.importorskip("hypothesis")  # optional dep: skip, not error
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import ckpt_io


def _lz4_available() -> bool:
    try:
        import lz4.frame  # noqa: F401
        return True
    except ImportError:
        return False


#: every lossless codec installed — byte-identity must hold on all of them
CODECS = ["none", "zlib"] + (["lz4"] if _lz4_available() else [])

#: runtime-state-shaped dtypes: KV/recurrent caches (f32/bf16/f8), RNG key
#: data (uint32), token cursors (int32), quantized caches (int8)
DTYPES = ["float32", "float64", "int8", "uint8", "int32", "uint32",
          "bfloat16", "float8_e4m3fn"]

#: 0-d, empty, single-element, and >1-chunk shapes (chunk_bytes below is 97,
#: so 257 f32 elements stream as 11 chunks)
SHAPES = [(), (0,), (1,), (3, 2), (257,), (33, 7)]

CHUNK_BYTES = 97


@st.composite
def payloads(draw):
    dtype = ckpt_io.resolve_dtype(draw(st.sampled_from(DTYPES)))
    shape = draw(st.sampled_from(SHAPES))
    n = int(np.prod(shape, dtype=np.int64))
    seed = draw(st.integers(0, 2**32 - 1))
    raw = np.random.RandomState(seed).bytes(n * dtype.itemsize)
    return np.frombuffer(raw, np.uint8).view(dtype).reshape(shape).copy()


def _write_read(arr, codec_name):
    codec = ckpt_io.get_codec(codec_name)
    with tempfile.TemporaryDirectory() as td:
        rdir = Path(td) / "rank00000"
        stats = ckpt_io.write_rank_shards(rdir, {"0.0": arr}, codec,
                                          chunk_bytes=CHUNK_BYTES,
                                          compute_digests=True)
        with ckpt_io.RankShardReader(rdir) as rd:
            entry = rd.entry("0.0")
            out = np.array(rd.read("0.0"))   # copy out of the mmap'd view
    return stats, entry, out


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(arr=payloads())
def test_roundtrip_byte_identity_and_digest_stability(arr):
    want = arr.tobytes()
    want_digest = ckpt_io.shard_digest(arr)
    for codec_name in CODECS:
        stats, entry, out = _write_read(arr, codec_name)
        assert out.dtype == arr.dtype and out.shape == arr.shape, \
            f"{codec_name}: dtype/shape mangled"
        assert out.tobytes() == want, f"{codec_name}: bytes diverged"
        # digest is over the RAW content — identical whatever the codec,
        # and the writer's fused inline hash must agree with shard_digest
        assert entry["digest"] == want_digest, \
            f"{codec_name}: digest not stable"
        assert stats["digests"]["0.0"] == want_digest
        # multi-chunk entries really are multi-chunk
        if arr.nbytes > CHUNK_BYTES:
            assert len(entry["chunks"]) > 1


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(arr=payloads(), seed=st.integers(0, 2**31 - 1))
def test_distinct_payloads_get_distinct_digests(arr, seed):
    other = arr.copy()
    if other.size:
        flat = other.view(np.uint8).reshape(-1)
        flat[seed % flat.size] ^= 0xFF
        if other.tobytes() != arr.tobytes():
            assert ckpt_io.shard_digest(other) != ckpt_io.shard_digest(arr)
    # dtype/shape-qualified: same bytes under another dtype != same digest
    if arr.dtype == np.float32 and arr.size:
        assert ckpt_io.shard_digest(arr.view(np.int32)) != \
            ckpt_io.shard_digest(arr)


def test_empty_cache_container_roundtrip():
    """An empty runtime snapshot (no decoded tokens yet, caches=None) writes
    an entry-less container that parses and reads back clean."""
    for codec_name in CODECS:
        codec = ckpt_io.get_codec(codec_name)
        with tempfile.TemporaryDirectory() as td:
            rdir = Path(td) / "rank00000"
            stats = ckpt_io.write_rank_shards(rdir, {}, codec,
                                              chunk_bytes=CHUNK_BYTES)
            assert stats["entries"] == {} and stats["raw_bytes"] == 0
            index = ckpt_io.read_rank_index(rdir)
            assert index["entries"] == {}
            assert (rdir / ckpt_io.BIN_NAME).exists()
