"""Zero-downtime elasticity: live rank join/leave without a restart.

The rescale gate: the world goes N -> N±1 UNDER LOAD with the training
loss curve continuous across the membership change — survivor parameters
byte-identical post-shrink (a live shrink never touches arrays), a
joined rank's slice digest-verified on arrival, and shrink downtime a
constant (drain + re-point), not a function of checkpoint size."""
import threading
import time
from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.configs import CkptIOConfig, smoke_config
from repro.core import Cluster, ckpt_io, elastic, faults
from repro.core.backends.fabric import DepartedRankError, Fabric
from repro.core.callspec import TAG_USER, handle_vid
from repro.core.ckpt_tiers import ReplicaTier, container_sha
from repro.core.faults import (FaultInjector, FaultPlan, FaultSpec,
                               PreemptNotice)
from repro.core.restore import repoint_world
from repro.core.supervisor import (Supervisor, SupervisorConfig,
                                   classify_failure)
from repro.launch.train import Trainer

WORLD = 4


def _io(**kw):
    kw.setdefault("codec", "zlib")
    kw.setdefault("incremental", True)
    kw.setdefault("drain_timeout", 1.0)
    return CkptIOConfig(**kw)


def _arrays(seed=3):
    rng = np.random.default_rng(seed)
    return {"w": jax.numpy.asarray(rng.normal(size=(64, 16))
                                   .astype(np.float32))}


def _cluster(tmp_path, world=WORLD):
    return Cluster(world, "mpich", ckpt_dir=tmp_path / "ck", ckpt_io=_io())


def _commit(c, step, arrays=None):
    c.checkpoint(step, arrays or _arrays(), None).wait()
    c.writer.wait_idle()
    return c.writer.latest()


def _allreduce_all(c):
    """One world allreduce entered by every member concurrently."""
    return c.run_collective(
        lambda m: m.allreduce(m.comm_world(), 1.0, m.op_handles["MPI_SUM"]))


@pytest.fixture(autouse=True)
def _clean_failpoints():
    yield
    faults.disarm_all()


# ---------------------------------------------------------------------------
# fabric: retirement + scavenging (the transport half of a leave)
# ---------------------------------------------------------------------------

def test_fabric_retire_scavenge_and_departed_send():
    f = Fabric(3)
    f.send(0, 2, 7, "queued-before-departure")
    triples = f.scavenge(2)
    assert triples == [(0, 7, "queued-before-departure")]
    f.retire(2)
    with pytest.raises(DepartedRankError) as ei:
        f.send(0, 2, 8, "too-late")
    assert ei.value.dst == 2
    # the fabric only ever grows; shrinking is expressed as retirement
    with pytest.raises(ValueError, match="never shrinks"):
        f.resize(2)
    f.resize(5)
    assert f.world_size == 5
    f.send(0, 4, 1, "new slot reachable")


# ---------------------------------------------------------------------------
# repoint_world: sparse-membership COMM_WORLD re-point, vid coherence
# ---------------------------------------------------------------------------

def test_repoint_world_vids_coherent_across_members(tmp_path):
    c = _cluster(tmp_path)
    old_vids = {r: handle_vid(c.mana(r).comm_world()) for r in range(WORLD)}
    assert len(set(old_vids.values())) == 1      # one ggid, no coordination
    c.remove_rank(1)
    stats = c.resize([0, 2, 3])
    assert set(stats) == {0, 2, 3}
    new_vids = {r: handle_vid(c.mana(r).comm_world()) for r in (0, 2, 3)}
    # identical member lists hash to identical ggids on every survivor,
    # and the old world vid is gone (freed before the new registration)
    assert len(set(new_vids.values())) == 1
    assert set(new_vids.values()) != set(old_vids.values())
    for r in (0, 2, 3):
        assert c.mana(r).world_size == 3
        assert c.mana(r).backend.comm_ranks(
            c.mana(r).backend.world_comm()) == [0, 2, 3]
    # a post-repoint collective over the sparse membership completes
    assert _allreduce_all(c) == [3.0, 3.0, 3.0]
    c.writer.close()


def test_repoint_world_purges_stale_internal_messages(tmp_path):
    c = _cluster(tmp_path, world=2)
    m0, m1 = c.mana(0), c.mana(1)
    m1.bcast(m1.comm_world(), "half-a-round", root=1)   # in flight
    from repro.core.drain import drain_rank
    drain_rank(m0)                       # buffers the internal bcast chunk
    m1.isend(0, tag=4, payload="user")
    drain_rank(m0)
    stats = repoint_world(m0, [0, 1])
    # the old round's internal message died with the old vid; user p2p
    # traffic survives the re-point untouched
    assert stats["purged_internal"] == 1
    assert [(s, t) for s, t, _ in m0.pending_messages] == [(1, TAG_USER + 4)]
    assert m0.recv(1, 4) == "user"
    c.writer.close()


def test_resize_rejects_dead_members(tmp_path):
    c = _cluster(tmp_path)
    c.halt_rank(2)
    with pytest.raises(ValueError, match="rank 2 is dead"):
        c.resize([0, 1, 2, 3])
    c.writer.close()


# ---------------------------------------------------------------------------
# shrink: the graceful-leave protocol end to end
# ---------------------------------------------------------------------------

def test_shrink_graceful_handoff_redelivery_and_repair(tmp_path):
    c = _cluster(tmp_path)
    tier = ReplicaTier()
    tier.replicate(c, _commit(c, 1))
    # in-flight user p2p addressed to the leaver, plus the leaver's own
    # buffered user message (drained earlier, never delivered)
    c.mana(0).backend.send(3, TAG_USER + 7, "for-the-leaver")
    c.mana(3).pending_messages.append((2, TAG_USER + 9, "leaver-held"))
    rep = elastic.shrink(c, 3, tier=tier, cursor={"next_index": 42},
                         timeout=5.0)
    assert rep.kind == "shrink" and rep.graceful
    assert rep.members == [0, 1, 2] and rep.inheritor == 0
    assert rep.workload_cursor == {"next_index": 42}
    assert rep.redelivered == 2          # scavenged msg + handed-off pending
    assert rep.cancelled == []           # no internal round was in flight
    assert rep.downtime_ms < 1000        # constant-bounded, not image-sized
    assert c.survivors() == [0, 1, 2]
    # the leaver's held containers moved to the inheritor; after repair the
    # image still assembles from survivors only
    assert any(k[1] == 3 for k in tier.stores[0])
    img = tier.image(c)
    assert img is not None and img.step == 1
    # redelivered traffic is receivable AT the inheritor, original metadata
    inh = c.mana(0)
    assert inh.recv(0, 7) == "for-the-leaver"
    assert inh.recv(2, 9) == "leaver-held"
    # the shrunken world is live: collective + departed-rank sends typed
    assert _allreduce_all(c) == [3.0, 3.0, 3.0]
    with pytest.raises(DepartedRankError):
        c.mana(1).backend.send(3, TAG_USER + 1, "ghost")
    assert ("rescaled", "shrink", 3, (0, 1, 2)) in [
        e[:4] for e in c.events if e[0] == "rescaled"]
    c.writer.close()


def test_shrink_dead_leaver_skips_handoff_serves_from_replicas(tmp_path):
    c = _cluster(tmp_path)
    tier = ReplicaTier()
    tier.replicate(c, _commit(c, 1))
    c.halt_rank(2)                       # died without a grace window
    rep = elastic.shrink(c, 2, tier=tier, timeout=5.0)
    assert not rep.graceful and rep.handoff_items == 0
    assert rep.members == [0, 1, 3]
    # the dead rank's newest container survives in its ring partner's RAM
    img = tier.image(c)
    assert img is not None and img.step == 1
    assert _allreduce_all(c) == [3.0, 3.0, 3.0]
    c.writer.close()


def test_shrink_last_member_is_typed(tmp_path):
    c = _cluster(tmp_path, world=1)
    with pytest.raises(elastic.RescaleError, match="last"):
        elastic.shrink(c, 0)
    c.writer.close()


# ---------------------------------------------------------------------------
# join: handshake, digest-verified slice stream, fencing
# ---------------------------------------------------------------------------

def test_join_streams_digest_verified_slice(tmp_path):
    c = _cluster(tmp_path, world=2)
    tier = ReplicaTier()
    tier.replicate(c, _commit(c, 1))
    rep = elastic.join(c, tier=tier, timeout=5.0)
    assert rep.kind == "join" and rep.members == [0, 1, rep.rank]
    assert rep.slice_verified is True
    assert rep.handoff_items == len(tier.stores[rep.rank])
    for (step, r), cont in tier.stores[rep.rank].items():
        assert cont.sha == container_sha(cont.data)
    assert c.survivors() == [0, 1, rep.rank]
    assert _allreduce_all(c) == [3.0, 3.0, 3.0]
    c.writer.close()


def test_join_timeout_fences_joiner_world_untouched(tmp_path):
    c = _cluster(tmp_path, world=2)
    members_before = c.survivors()
    vids_before = {r: handle_vid(c.mana(r).comm_world())
                   for r in members_before}

    def stall(name, ctx):
        faults.disarm("elastic.join.ready", stall)
        raise faults.InjectedFault(
            f"injected join stall: rank {ctx.get('rank')} wedged")

    faults.arm("elastic.join.ready", stall)
    with pytest.raises(elastic.JoinTimeoutError) as ei:
        elastic.join(c, timeout=1.0)
    fenced = ei.value.rank
    # the running world never saw the joiner: membership, world vids, and
    # collectives all exactly as before; the fenced slot is unreachable
    assert c.survivors() == members_before
    assert {r: handle_vid(c.mana(r).comm_world())
            for r in members_before} == vids_before
    assert _allreduce_all(c) == [2.0, 2.0]
    with pytest.raises(DepartedRankError):
        c.mana(0).backend.send(fenced, TAG_USER + 1, "ghost")
    assert any(e[0] == "join_fenced" and e[1] == fenced for e in c.events)
    c.writer.close()


def test_injected_join_timeout_fault_arms_the_failpoint(tmp_path):
    c = _cluster(tmp_path, world=2)
    with FaultInjector(FaultPlan([FaultSpec("join_timeout",
                                            at_step=1)])) as inj:
        inj.on_step(1, c)
        with pytest.raises(elastic.JoinTimeoutError):
            elastic.join(c, timeout=1.0)
    assert c.survivors() == [0, 1]
    c.writer.close()


# ---------------------------------------------------------------------------
# trainer under load: loss continuity + byte-identical survivor params
# ---------------------------------------------------------------------------

STEPS, EVERY = 9, 3


def _tiny_cfg():
    return replace(smoke_config("granite-3-2b"), n_layers=1, d_model=32,
                   n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
                   vocab_size=128, vocab_pad_multiple=64)


def _trainer(ckpt_dir, world=WORLD):
    return Trainer(_tiny_cfg(), batch_size=4, seq_len=16, world_size=world,
                   ckpt_dir=ckpt_dir, total_steps=STEPS, ckpt_io=_io())


def _digests(tr):
    leaves = jax.tree.leaves({"p": tr.params, "o": tr.opt_state})
    return [ckpt_io.shard_digest(jax.device_get(leaf)) for leaf in leaves]


def test_live_shrink_under_load_params_byte_identical(tmp_path):
    tr = _trainer(tmp_path / "ck")
    tr.init_state()
    try:
        tr.run(4, ckpt_every=2, log_every=1)
        before = _digests(tr)
        step_before = tr.step
        rep = elastic.shrink(tr.cluster, 3,
                             cursor=tr.prepare_leave(3), timeout=5.0)
        tr.rescale(rep)
        # the membership change never touched arrays or the step counter
        assert _digests(tr) == before
        assert tr.step == step_before
        # ...and training CONTINUES on the survivors: the loss curve is one
        # unbroken trajectory (deterministic pipeline cursor, no rewind)
        tr.run(3, ckpt_every=0, log_every=1)
        assert tr.step == step_before + 3
        steps = [h["step"] for h in tr.history]
        assert steps == sorted(set(steps))       # strictly forward, no replay
        assert all(np.isfinite(h["loss"]) for h in tr.history)
    finally:
        tr.pipeline.stop()
        tr.cluster.writer.close()


def test_live_shrink_of_pipeline_owner_reshards_cursor(tmp_path):
    tr = _trainer(tmp_path / "ck")
    tr.init_state()
    try:
        tr.run(2, ckpt_every=0, log_every=100)
        cursor_before = tr.pipeline.state()["next_index"]
        cursor = tr.prepare_leave(0)             # rank 0 OWNS the pipeline
        assert cursor is not None
        assert cursor["next_index"] == cursor_before
        rep = elastic.shrink(tr.cluster, 0, cursor=cursor, timeout=5.0)
        tr.rescale(rep)
        # reattached on a survivor, resuming from the same counter
        assert tr.pipeline.mana.rank == rep.members[0]
        assert tr.pipeline.state()["next_index"] == cursor_before
        tr.run(2, ckpt_every=0, log_every=100)
        assert all(np.isfinite(h["loss"]) for h in tr.history)
    finally:
        tr.pipeline.stop()
        tr.cluster.writer.close()


# ---------------------------------------------------------------------------
# supervisor: the rescale rung
# ---------------------------------------------------------------------------

def test_classify_preempt_notice():
    assert classify_failure(PreemptNotice(2, 3.0)) == ("preempt_notice", 2)


def _supervised(tmp_path, specs, world=WORLD, **cfg_kw):
    cfg_kw.setdefault("backoff_floor_s", 0.01)
    cfg_kw.setdefault("backoff_ceiling_s", 0.05)
    tr = _trainer(tmp_path / "ck", world=world)
    tr.init_state()
    with FaultInjector(FaultPlan(specs)) as inj:
        sup = Supervisor(tr, injector=inj, lease_s=1.0, verbose=False,
                         tier=ReplicaTier(),
                         config=SupervisorConfig(**cfg_kw))
        incidents = sup.run(STEPS, ckpt_every=EVERY)
    return tr, incidents


def test_supervised_preempt_rescale_rung_no_rewind(tmp_path):
    tr, incidents = _supervised(
        tmp_path, [FaultSpec("preempt_notice", at_step=5, rank=3)])
    try:
        assert [i.kind for i in incidents] == ["preempt_notice"]
        inc = incidents[0]
        assert inc.tier == "rescale" and inc.ckpt is None
        # no rewind: the loss curve continues at the very step the notice
        # arrived, on the shrunken world
        assert inc.resumed_step == inc.step == 5
        assert inc.world_before == WORLD and inc.world_after == WORLD - 1
        assert tr.step == STEPS
        assert tr.cluster.survivors() == [0, 1, 2]
        assert any(e[0] == "rescaled" for e in tr.cluster.events)
        # post-shrink checkpoints carry the sparse membership
        tr.cluster.writer.wait_idle()
        from repro.core.restore import load_manifest
        man = load_manifest(tr.cluster.writer.latest())
        assert man["members"] == [0, 1, 2]
    finally:
        tr.pipeline.stop()
        tr.cluster.writer.close()


def test_supervised_rescale_off_falls_through_to_ladder(tmp_path):
    # policy "off": the notice is handled like any fencing failure —
    # victim fenced, restore ladder walked, step rewound to the checkpoint
    tr, incidents = _supervised(
        tmp_path, [FaultSpec("preempt_notice", at_step=5, rank=3)],
        rescale="off")
    try:
        inc = incidents[0]
        assert inc.kind == "preempt_notice"
        assert inc.tier in ("ram", "disk", "disk_chain")
        assert inc.resumed_step == 3
        assert tr.step == STEPS
    finally:
        tr.pipeline.stop()
        tr.cluster.writer.close()


def test_supervised_rescale_all_serves_rank_dead(tmp_path):
    # policy "all": even an ungraceful death is resized around — the dead
    # rank's replicas serve from its ring partner, nothing rewinds
    tr, incidents = _supervised(
        tmp_path, [FaultSpec("kill_rank", at_step=5, rank=3)],
        rescale="all")
    try:
        inc = incidents[0]
        assert inc.kind == "rank_dead" and inc.tier == "rescale"
        assert inc.resumed_step == inc.step
        assert tr.cluster.survivors() == [0, 1, 2]
        assert tr.step == STEPS
    finally:
        tr.pipeline.stop()
        tr.cluster.writer.close()


def test_supervised_shrink_downtime_beats_restore(tmp_path):
    # the rescale gate's latency half: a live shrink must be cheaper than
    # the SAME failure recovered through the restore ladder's RAM rung
    tr1, inc1 = _supervised(
        tmp_path / "a", [FaultSpec("preempt_notice", at_step=5, rank=3)])
    tr1.pipeline.stop()
    tr1.cluster.writer.close()
    tr2, inc2 = _supervised(
        tmp_path / "b", [FaultSpec("preempt_notice", at_step=5, rank=3)],
        rescale="off")
    tr2.pipeline.stop()
    tr2.cluster.writer.close()
    assert inc1[0].tier == "rescale" and inc2[0].tier in ("ram", "disk")
    assert inc1[0].timings["restore_ms"] < inc2[0].timings["restore_ms"]


# ---------------------------------------------------------------------------
# grow under supervision: shrink then live join back to full strength
# ---------------------------------------------------------------------------

def test_shrink_then_join_roundtrip_under_load(tmp_path):
    tr = _trainer(tmp_path / "ck")
    tr.init_state()
    tier = ReplicaTier()
    try:
        tr.run(3, ckpt_every=3, log_every=100)
        tr.cluster.writer.wait_idle()
        tier.attach(tr.cluster)
        tier.drain_commits(tr.cluster)
        rep = elastic.shrink(tr.cluster, 3, tier=tier,
                             cursor=tr.prepare_leave(3), timeout=5.0)
        tr.rescale(rep)
        tr.run(2, ckpt_every=0, log_every=100)
        grown = elastic.join(tr.cluster, tier=tier, timeout=5.0)
        assert grown.slice_verified in (True, None)
        assert len(tr.cluster.survivors()) == WORLD
        tr.run(2, ckpt_every=0, log_every=100)
        assert tr.step == 7
        steps = [h["step"] for h in tr.history]
        assert steps == sorted(set(steps))
        assert all(np.isfinite(h["loss"]) for h in tr.history)
    finally:
        tr.pipeline.stop()
        tr.cluster.writer.close()
