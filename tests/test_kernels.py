"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret=True
executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_chunk import gla_chunk

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,S,D,window", [
    (2, 4, 2, 128, 64, None),
    (1, 4, 4, 256, 32, None),
    (2, 6, 2, 128, 128, 32),
    (1, 2, 1, 64, 96, None),       # non-MXU-aligned head dim -> padded
    (1, 8, 2, 64, 64, 16),
])
def test_flash_attention_sweep(B, H, K, S, D, window, dtype):
    ks = jax.random.split(jax.random.key(S * D + H), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, K, S, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, K, S, D), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, window=window, q_block=64, kv_block=64,
                          interpret=True)
    want = ref.naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=TOL[dtype], atol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,S,D,length,window", [
    (2, 4, 2, 64, 64, 50, None),
    (1, 8, 1, 128, 32, 128, None),
    (2, 4, 4, 64, 64, 33, 16),
    (1, 2, 2, 96, 128, 7, None),   # S not divisible by n_splits -> adjusted
])
def test_decode_attention_sweep(B, H, K, S, D, length, window, dtype):
    ks = jax.random.split(jax.random.key(S + D + length), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32).astype(dtype)
    out = decode_attention(q, k, v, length, n_splits=8, window=window,
                           interpret=True)
    want = ref.naive_decode_attention(q, jnp.moveaxis(k, 1, 2),
                                      jnp.moveaxis(v, 1, 2), length,
                                      window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=TOL[dtype], atol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,N,P,chunk", [
    (2, 3, 64, 32, 32, 16),
    (1, 2, 128, 16, 64, 32),
    (1, 1, 96, 8, 8, 32),          # S % chunk != 0 -> chunk halved
])
def test_gla_chunk_sweep(B, H, S, N, P, chunk, dtype):
    ks = jax.random.split(jax.random.key(S * N), 4)
    q = jax.random.normal(ks[0], (B, S, H, N), jnp.float32).astype(dtype)
    k = (jax.random.normal(ks[1], (B, S, H, N), jnp.float32) * 0.3).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, P), jnp.float32).astype(dtype)
    lg = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H))) * 0.3
    out = gla_chunk(q, k, v, lg, chunk=chunk, interpret=True)
    want, _ = ref.naive_gla(q, k, v, lg)
    tol = {jnp.float32: 5e-4, jnp.bfloat16: 5e-2}[dtype]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_ops_dispatch_uses_ref_on_cpu():
    from repro.kernels import ops
    B, H, K, S, D = 1, 2, 2, 32, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, K, S, D))
    v = jax.random.normal(ks[2], (B, K, S, D))
    out = ops.flash_attention(q, k, v)
    want = ref.naive_attention(q, k, v)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)
