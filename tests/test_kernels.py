"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret=True
executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_chunk import gla_chunk

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,S,D,window", [
    (2, 4, 2, 128, 64, None),
    (1, 4, 4, 256, 32, None),
    (2, 6, 2, 128, 128, 32),
    (1, 2, 1, 64, 96, None),       # non-MXU-aligned head dim -> padded
    (1, 8, 2, 64, 64, 16),
])
def test_flash_attention_sweep(B, H, K, S, D, window, dtype):
    ks = jax.random.split(jax.random.key(S * D + H), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, K, S, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, K, S, D), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, window=window, q_block=64, kv_block=64,
                          interpret=True)
    want = ref.naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=TOL[dtype], atol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,S,D,length,window", [
    (2, 4, 2, 64, 64, 50, None),
    (1, 8, 1, 128, 32, 128, None),
    (2, 4, 4, 64, 64, 33, 16),
    (1, 2, 2, 96, 128, 7, None),   # S not divisible by n_splits -> adjusted
])
def test_decode_attention_sweep(B, H, K, S, D, length, window, dtype):
    ks = jax.random.split(jax.random.key(S + D + length), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32).astype(dtype)
    out = decode_attention(q, k, v, length, n_splits=8, window=window,
                           interpret=True)
    want = ref.naive_decode_attention(q, jnp.moveaxis(k, 1, 2),
                                      jnp.moveaxis(v, 1, 2), length,
                                      window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=TOL[dtype], atol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,N,P,chunk", [
    (2, 3, 64, 32, 32, 16),
    (1, 2, 128, 16, 64, 32),
    (1, 1, 96, 8, 8, 32),          # S % chunk != 0 -> chunk halved
])
def test_gla_chunk_sweep(B, H, S, N, P, chunk, dtype):
    ks = jax.random.split(jax.random.key(S * N), 4)
    q = jax.random.normal(ks[0], (B, S, H, N), jnp.float32).astype(dtype)
    k = (jax.random.normal(ks[1], (B, S, H, N), jnp.float32) * 0.3).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, P), jnp.float32).astype(dtype)
    lg = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H))) * 0.3
    out = gla_chunk(q, k, v, lg, chunk=chunk, interpret=True)
    want, _ = ref.naive_gla(q, k, v, lg)
    tol = {jnp.float32: 5e-4, jnp.bfloat16: 5e-2}[dtype]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_ops_dispatch_uses_ref_on_cpu():
    from repro.kernels import ops
    B, H, K, S, D = 1, 2, 2, 32, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, K, S, D))
    v = jax.random.normal(ks[2], (B, K, S, D))
    out = ops.flash_attention(q, k, v)
    want = ref.naive_attention(q, k, v)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# paged decode (DMA-gathered KV pool via scalar-prefetch page table)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 24])
def test_paged_decode_attention(window):
    from repro.kernels.decode_attention import paged_decode_attention
    B, H, K, D = 2, 4, 2, 64
    page_size, n_pages = 16, 4
    S = page_size * n_pages
    n_pool = B * n_pages + 3           # pool bigger than needed, shuffled
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k_pool = jax.random.normal(ks[1], (n_pool, page_size, K, D))
    v_pool = jax.random.normal(ks[2], (n_pool, page_size, K, D))
    rng = np.random.default_rng(0)
    pt = rng.permutation(n_pool)[:B * n_pages].reshape(B, n_pages)
    lengths = np.array([S - 5, 2 * page_size - 3], np.int32)
    # entries past length must stay VALID pool indices (contract: use 0)
    pt_masked = pt.copy()
    for b in range(B):
        pt_masked[b, (lengths[b] + page_size - 1) // page_size:] = 0
    out = paged_decode_attention(q, k_pool, v_pool,
                                 jnp.asarray(pt_masked, jnp.int32),
                                 jnp.asarray(lengths), window=window,
                                 interpret=True)
    for b in range(B):
        # gather the contiguous cache this page table encodes, then oracle
        kc = np.concatenate([np.asarray(k_pool[pt[b, p]])
                             for p in range(n_pages)])[None]  # [1,S,K,D]
        vc = np.concatenate([np.asarray(v_pool[pt[b, p]])
                             for p in range(n_pages)])[None]
        want = ref.naive_decode_attention(
            q[b:b + 1], jnp.moveaxis(jnp.asarray(kc), 1, 2),
            jnp.moveaxis(jnp.asarray(vc), 1, 2), int(lengths[b]),
            window=window)
        np.testing.assert_allclose(np.asarray(out[b:b + 1]),
                                   np.asarray(want), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# chunk-parallel GLA (associative-scan state carry)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,N,P,chunk", [
    (2, 3, 64, 32, 32, 16),
    (1, 2, 96, 16, 32, 32),            # S % chunk != 0 -> chunk halved
])
def test_gla_chunk_parallel_matches_oracle(B, H, S, N, P, chunk):
    from repro.kernels.mlstm_chunk import gla_chunk_parallel
    ks = jax.random.split(jax.random.key(S * N + 1), 4)
    q = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N)) * 0.3
    v = jax.random.normal(ks[2], (B, S, H, P))
    lg = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H))) * 0.3
    out = gla_chunk_parallel(q, k, v, lg, chunk=chunk, interpret=True)
    want, _ = ref.naive_gla(q, k, v, lg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# blocked XLA fast paths (the CPU/GPU production dispatch targets)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window,S", [
    (True, None, 128),
    (True, 32, 128),
    (False, None, 128),
    (True, None, 80),                  # S not a multiple of the q block
    (True, 17, 96),                    # odd window, odd-ish S
])
def test_xla_flash_matches_ref(causal, window, S):
    from repro.kernels import xla_fast
    B, H, K, D = 2, 4, 2, 64
    ks = jax.random.split(jax.random.key(S), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, K, S, D))
    v = jax.random.normal(ks[2], (B, K, S, D))
    out = xla_fast.flash_attention_xla(q, k, v, causal=causal, window=window,
                                       q_block=32)
    want = ref.naive_attention(q, k, v, causal=causal,
                               window=window if causal else None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("length,window", [(90, None), (64, 16), (7, None)])
def test_xla_decode_matches_ref(length, window):
    from repro.kernels import xla_fast
    B, H, K, S, D = 2, 4, 2, 96, 64
    ks = jax.random.split(jax.random.key(length), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    out = xla_fast.decode_attention_xla(q, k, v, length, window=window)
    want = ref.naive_decode_attention(q, jnp.moveaxis(k, 1, 2),
                                      jnp.moveaxis(v, 1, 2), length,
                                      window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# tuned-vs-default block resolution (the cache consult path)
# ---------------------------------------------------------------------------

def test_flash_tuned_blocks_from_cache(tmp_path, monkeypatch):
    """tune() persists a winner; a later call with block=None resolves it
    from the cache and matches both the oracle and the default-block path."""
    from repro.kernels import flash_attention as fa
    from repro.kernels import tuning
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "cache.json"))
    B, H, K, S, D = 1, 2, 2, 64, 32
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, K, S, D))
    v = jax.random.normal(ks[2], (B, K, S, D))
    default = fa.flash_attention(q, k, v, interpret=True)  # cache miss
    win = fa.tune(q, k, v, trials=1,
                  candidates=((32, 32), (64, 64)), interpret=True)
    assert {"q_block", "kv_block"} <= set(win)
    key = tuning.make_key("flash_attention", jax.default_backend(), q.dtype,
                          S=S, D=D, causal=1, window=0)
    assert tuning.lookup("flash_attention", key) is not None
    tuned = fa.flash_attention(q, k, v, interpret=True)    # cache hit
    want = ref.naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(tuned), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(tuned), np.asarray(default),
                               rtol=1e-6, atol=1e-6)
