"""The implementation-oblivious property: ONE interpose codebase, four
backends, identical observable semantics — plus fast/slow translation paths."""
import threading

import pytest

from repro.core import Cluster, Kind
from repro.core.drain import drain_rank

ALL = ["mpich", "craympi", "openmpi", "exampi"]


def split_all(cluster, color_fn, key_fn=lambda r: r):
    out = [None] * cluster.world_size

    def run(r):
        m = cluster.mana(r)
        out[r] = m.comm_split(m.comm_world(), color_fn(r), key_fn(r))

    ts = [threading.Thread(target=run, args=(r,))
          for r in range(cluster.world_size)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=30)
    return out


@pytest.mark.parametrize("backend", ALL)
def test_world_and_split_semantics(backend):
    c = Cluster(4, backend)
    m0 = c.mana(0)
    w = m0.comm_world()
    assert m0.comm_size(w) == 4
    assert c.mana(2).comm_rank(c.mana(2).comm_world()) == 2
    subs = split_all(c, lambda r: r % 2)
    # handles are rank-agreed (ggid) and color-distinct
    assert subs[0] == subs[2] != subs[1] == subs[3]
    assert m0.comm_size(subs[0]) == 2
    # vid is embedded in the low 32 bits of the 64-bit handle
    from repro.core import handle_vid, vid_kind
    assert vid_kind(handle_vid(subs[0])) == Kind.COMM


@pytest.mark.parametrize("backend", ALL)
def test_groups_types_ops(backend):
    c = Cluster(2, backend)
    m = c.mana(0)
    g = m.comm_group(m.comm_world())
    assert m.group_ranks(g) == [0, 1]
    t = m.type_contiguous(5, m.dtype_handles["MPI_DOUBLE"])
    env = m.type_envelope(t)
    assert env["combiner"] == "contiguous" and env["count"] == 5
    assert env["base"]["name"] == "MPI_DOUBLE"
    o = m.op_create("logsumexp", commutative=False)
    assert m._desc(o).meta["commutative"] is False


@pytest.mark.parametrize("backend", ALL)
def test_p2p_and_requests(backend):
    c = Cluster(2, backend)
    m0, m1 = c.mana(0), c.mana(1)
    req = m0.isend(1, tag=3, payload=[1, 2, 3])
    assert m0.test(req) is True
    assert m1.iprobe() == (0, 3 + 50000)
    assert m1.recv(0, 3) == [1, 2, 3]
    assert m1.iprobe() is None


def test_exampi_split_emulated_via_core_subset():
    """ExaMPI has no comm_split — the interpose layer must emulate it and the
    result must be indistinguishable (paper §5)."""
    c = Cluster(4, "exampi")
    subs = split_all(c, lambda r: r // 2)
    m0 = c.mana(0)
    assert m0.comm_size(subs[0]) == 2
    assert sorted(m0._desc(subs[0]).meta["ranks"]) == [0, 1]


def test_slow_vs_fast_translation_equivalent():
    """The legacy (string-keyed, multi-map) path returns the same physical
    handles — it is only slower (benchmarked in bench_vid)."""
    cf = Cluster(2, "mpich", translation="fast")
    cs = Cluster(2, "mpich", translation="slow")
    for c in (cf, cs):
        m = c.mana(0)
        t = m.type_contiguous(2, m.dtype_handles["MPI_INT32_T"])
        assert m.type_envelope(t)["count"] == 2
    # physical handles are identical because mpich constants are fixed ints
    assert cf.mana(0)._phys(cf.mana(0).dtype_handles["MPI_FLOAT"]) == \
        cs.mana(0)._phys(cs.mana(0).dtype_handles["MPI_FLOAT"])


@pytest.mark.parametrize("backend", ALL)
def test_creation_log_records_everything(backend):
    c = Cluster(2, backend)
    m = c.mana(0)
    m.comm_create([0, 1])
    m.type_contiguous(2, m.dtype_handles["MPI_FLOAT"])
    m.op_create("x")
    ops = [e[0] for e in m.log]
    assert ops == ["comm_create", "type_create", "op_create"]


def test_drain_completes_requests_and_buffers_messages():
    c = Cluster(2, "openmpi")
    m0, m1 = c.mana(0), c.mana(1)
    m0.isend(1, tag=1, payload="a")
    m0.isend(1, tag=2, payload="b")
    st = drain_rank(m1)
    assert st["messages_buffered"] == 2
    assert c.fabric.pending_count(1) == 0           # network empty
    # buffered messages are consumed transparently after drain
    assert m1.recv(0, 2) == "b"
    assert m1.recv(0, 1) == "a"


def test_free_then_use_raises():
    c = Cluster(2, "mpich")
    m = c.mana(0)
    h = m.comm_create([0, 1])
    m.comm_free(h)
    with pytest.raises(KeyError):
        m.comm_size(h)
