"""The declarative call-spec registry: generated-wrapper parity across
translation modes and flavors, the complete collective surface
(native AND derived), typed free errors, and the coverage gates.

The load-bearing property: ONE workload driven through the generated
wrappers produces IDENTICAL call transcripts and record-replay logs under
``translation='fast'``, ``'slow'`` and ``'none'``, on every backend flavor
— uniformity is structural, so the three translation mechanisms cannot
drift behaviorally."""
import threading

import numpy as np
import pytest

from repro.core import BACKENDS, Cluster, Kind
from repro.core.callspec import (COLLECTIVE_CALLS, REGISTRY, HandleFreeError,
                                 HandleKindError, NotInCommunicatorError,
                                 Policy, ReduceOpError, spec_for)
from repro.core.drain import drain_rank

ALL = sorted(BACKENDS)
MODES = ("fast", "slow", "none")
WORLD = 4


def run_coll(cluster, fn, ranks=None):
    """Drive a collective: every (selected) rank enters fn on its own
    thread, results in rank order."""
    ranks = range(cluster.world_size) if ranks is None else ranks
    out = {}
    errs = []

    def run(r):
        try:
            out[r] = fn(cluster.mana(r))
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=run, args=(r,)) for r in ranks]
    [t.start() for t in ts]
    [t.join(timeout=30) for t in ts]
    if errs:
        raise errs[0]
    return [out[r] for r in ranks]


def full_workload(cluster):
    """Exercise EVERY generated wrapper once (the meta-test asserts the
    transcript covers the whole registry)."""
    m0 = cluster.mana(0)
    w = m0.comm_world()
    m0.comm_rank(w)
    m0.comm_size(w)
    subs = run_coll(cluster, lambda m: m.comm_split(m.comm_world(),
                                                    m.rank % 2, m.rank))
    cc = m0.comm_create([0, 1])
    g = m0.comm_group(cc)
    m0.group_ranks(g)
    t = m0.type_contiguous(4, m0.dtype_handles["MPI_INT8_T"])
    m0.type_vector(2, 3, 8, t)
    m0.type_envelope(t)
    op = m0.op_create("logsumexp", commutative=False)
    assert op is not None
    m0.comm_free(cc)
    # p2p + requests
    r1 = m0.isend(1, tag=7, payload={"k": 1})
    r2 = m0.isend(1, tag=8, payload=[1, 2])
    gr = m0.grequest_start("prefetch", index=3, done=True)
    m0.test(r1)
    m0.test_all([r1, r2])
    m0.waitany([r1, r2])
    m0.waitsome([r1, r2])
    m0.wait_all([r1, r2])
    m0.request_free(gr)
    m1 = cluster.mana(1)
    m1.iprobe()
    m1.recv(0, 7)
    m1.recv(0, 8)
    # the full collective surface over world and a split comm
    s = m0.op_handles["MPI_SUM"]
    run_coll(cluster, lambda m: m.bcast(m.comm_world(), m.rank * 11,
                                        root=1))
    run_coll(cluster, lambda m: m.reduce(m.comm_world(), m.rank,
                                         m.op_handles["MPI_SUM"], root=0))
    run_coll(cluster, lambda m: m.allreduce(m.comm_world(), m.rank + 1,
                                            m.op_handles["MPI_SUM"]))
    run_coll(cluster, lambda m: m.scatter(
        m.comm_world(), [f"c{q}" for q in range(WORLD)]
        if m.rank == 2 else None, root=2))
    run_coll(cluster, lambda m: m.gather(m.comm_world(), m.rank, root=3))
    run_coll(cluster, lambda m: m.allgather(m.comm_world(), m.rank * 2))
    run_coll(cluster, lambda m: m.reduce_scatter(
        m.comm_world(), [m.rank] * WORLD, m.op_handles["MPI_SUM"]))
    run_coll(cluster, lambda m: m.scan(m.comm_world(), 1,
                                       m.op_handles["MPI_SUM"]))
    run_coll(cluster, lambda m: m.alltoall(
        m.comm_world(), [(m.rank, q) for q in range(WORLD)]))
    # a collective on the SPLIT communicator (members {0, 2})
    run_coll(cluster, lambda m: m.allreduce(subs[m.rank], m.rank, s),
             ranks=[0, 2])
    m0.barrier(expected=1)
    return subs


# ---------------------------------------------------------------------------
# translation-mode parity: fast / slow / none — identical transcripts+logs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ALL)
def test_translation_mode_parity(backend):
    captures = {}
    for mode in MODES:
        c = Cluster(WORLD, backend, translation=mode)
        full_workload(c)
        captures[mode] = [(list(c.mana(r).transcript), list(c.mana(r).log))
                          for r in range(WORLD)]
    for mode in ("slow", "none"):
        for r in range(WORLD):
            assert captures[mode][r][0] == captures["fast"][r][0], \
                f"{backend}/{mode}: rank {r} transcript diverged from fast"
            assert captures[mode][r][1] == captures["fast"][r][1], \
                f"{backend}/{mode}: rank {r} record-replay log diverged"


def test_workload_covers_every_generated_wrapper():
    """The parity workload must touch EVERY registry entry — a new
    CallSpec without parity coverage fails here."""
    c = Cluster(WORLD, "mpich")
    full_workload(c)
    called = set()
    for r in range(WORLD):
        called.update(name for name, _, _ in c.mana(r).transcript)
    missing = {s.name for s in REGISTRY} - called
    assert not missing, f"wrappers never exercised: {sorted(missing)}"


def test_transcripts_identical_across_flavors():
    """vids are deterministic (ggid + counters), so the SAME workload
    yields the same canonical transcript under every flavor — physical
    handles never leak into transcripts.  Envelope-returning calls are
    excluded: ExaMPI's INT8/CHAR aliasing makes their RESULTS differ by
    design (§4.3), which is exactly what the restore-side envelope
    re-encode translates."""
    aliasing_sensitive = {"type_envelope"}
    base = None
    for backend in ALL:
        c = Cluster(WORLD, backend, translation="fast")
        full_workload(c)
        t0 = [e for e in c.mana(0).transcript
              if e[0] not in aliasing_sensitive]
        if base is None:
            base = (backend, t0)
        else:
            assert t0 == base[1], f"{backend} transcript != {base[0]}"


# ---------------------------------------------------------------------------
# collective semantics, native and derived
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ALL)
def test_collective_results(backend):
    c = Cluster(WORLD, backend)
    s = lambda m: m.op_handles["MPI_SUM"]  # noqa: E731
    assert run_coll(c, lambda m: m.allreduce(m.comm_world(), m.rank + 1,
                                             s(m))) == [10] * WORLD
    assert run_coll(c, lambda m: m.bcast(m.comm_world(),
                                         {"v": 7} if m.rank == 2 else None,
                                         root=2)) == [{"v": 7}] * WORLD
    red = run_coll(c, lambda m: m.reduce(m.comm_world(), m.rank,
                                         m.op_handles["MPI_MAX"], root=1))
    assert red == [None, 3, None, None]
    assert run_coll(c, lambda m: m.gather(m.comm_world(), m.rank * 10,
                                          root=0))[0] == [0, 10, 20, 30]
    assert run_coll(c, lambda m: m.allgather(m.comm_world(), m.rank)) \
        == [[0, 1, 2, 3]] * WORLD
    assert run_coll(c, lambda m: m.scatter(
        m.comm_world(), list("abcd") if m.rank == 0 else None, root=0)) \
        == ["a", "b", "c", "d"]
    assert run_coll(c, lambda m: m.reduce_scatter(
        m.comm_world(), [m.rank] * WORLD, s(m))) == [6] * WORLD
    assert run_coll(c, lambda m: m.scan(m.comm_world(), m.rank + 1,
                                        s(m))) == [1, 3, 6, 10]
    at = run_coll(c, lambda m: m.alltoall(
        m.comm_world(), [(m.rank, q) for q in range(WORLD)]))
    for q in range(WORLD):
        assert at[q] == [(src, q) for src in range(WORLD)]


def test_native_vs_derived_equivalence():
    """mpich (full native caps) and fabric (zero collective caps — pure
    derived) must be observationally identical, including array payload
    folds."""
    results = {}
    for backend in ("mpich", "fabric"):
        c = Cluster(WORLD, backend)
        caps = c.mana(0).backend.capabilities()
        assert ("allreduce" in caps) == (backend == "mpich")
        arr = run_coll(c, lambda m: m.allreduce(
            m.comm_world(), np.full(3, m.rank, np.int64),
            m.op_handles["MPI_SUM"]))
        scn = run_coll(c, lambda m: m.scan(m.comm_world(), m.rank + 1,
                                           m.op_handles["MPI_PROD"]))
        results[backend] = (arr, scn)
    m_arr, m_scn = results["mpich"]
    f_arr, f_scn = results["fabric"]
    for a, b in zip(m_arr, f_arr):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, np.full(3, 6, np.int64))
    assert m_scn == f_scn == [1, 2, 6, 24]


def test_collective_on_split_comm_and_membership_errors():
    c = Cluster(WORLD, "exampi")       # no native split AND partial colls
    subs = run_coll(c, lambda m: m.comm_split(m.comm_world(), m.rank % 2,
                                              m.rank))
    got = run_coll(c, lambda m: m.allreduce(subs[m.rank], m.rank,
                                            m.op_handles["MPI_SUM"]),
                   ranks=[1, 3])
    assert got == [4, 4]
    # a non-member driving a collective on a comm it merely HOLDS is typed
    # (vid tables are per-rank, so the handle must come from rank 1's own
    # table: create the {0,2} communicator locally)
    foreign = c.mana(1).comm_create([0, 2])
    with pytest.raises(NotInCommunicatorError):
        c.mana(1).bcast(foreign, 1, root=0)
    with pytest.raises(ReduceOpError):
        op = c.mana(0).op_create("median", commutative=False)
        c.mana(0).allreduce(subs[0], 1, op)
    with pytest.raises(ValueError):
        c.mana(0).bcast(subs[0], 1, root=9)


def test_collective_drain_redelivers_after_restart(tmp_path):
    """A collective in flight at checkpoint time (root entered, peers not
    yet) drains into the image and re-delivers through the buffered
    receive after restart.  Scatter's fan-out is root->each-member under
    EVERY flavor (no tree shapes), so the drained pattern completes under
    ANY restart flavor of the matrix — here mpich -> fabric."""
    c = Cluster(WORLD, "mpich", ckpt_dir=tmp_path / "ck")
    m1 = c.mana(1)
    m1.scatter(m1.comm_world(), [f"s{q}" for q in range(WORLD)], root=1)
    req = c.checkpoint(3, {"x": np.arange(4.0)}, None)
    req.wait()
    # the drain buffered the in-flight fan-out (one message per peer)
    from repro.core.restore import load_rank_state
    drained = sum(load_rank_state(req.directory,
                                  r)["drain"]["coll_messages_buffered"]
                  for r in range(WORLD))
    assert drained >= WORLD - 1
    fresh = c.restart(req.directory, new_backend="fabric")
    for r in (0, 2, 3):
        m = fresh.mana(r)
        assert m.scatter(m.comm_world(), None, root=1) == f"s{r}"
    assert any(st["pending_collective"] >= 1 for st in fresh.rebind_stats)
    fresh.writer.close()
    c.writer.close()


def test_tree_collective_resumes_within_family(tmp_path):
    """MPICH's binomial-tree bcast forwards through intermediate ranks, so
    a mid-flight tree bcast resumes when the restart flavor REPLAYS the
    same message pattern — i.e. within the implementation family
    (mpich -> craympi); peers complete concurrently, forwarding down the
    drained tree."""
    c = Cluster(WORLD, "mpich", ckpt_dir=tmp_path / "ck")
    m1 = c.mana(1)
    m1.bcast(m1.comm_world(), {"payload": 42}, root=1)   # root's half only
    req = c.checkpoint(5, {"x": np.arange(4.0)}, None)
    req.wait()
    fresh = c.restart(req.directory, new_backend="craympi")
    got = run_coll(fresh, lambda m: m.bcast(m.comm_world(), None, root=1),
                   ranks=[0, 2, 3])
    assert got == [{"payload": 42}] * 3
    fresh.writer.close()
    c.writer.close()


def test_wildcard_iprobe_never_leaks_internal_tags(tmp_path):
    """A wildcard iprobe must not surface drained (or live) collective
    payloads as user messages: the leaked pseudo-tag could never be
    recv()'d and would wedge probe-driven message loops."""
    c = Cluster(WORLD, "mpich", ckpt_dir=tmp_path / "ck")
    m1 = c.mana(1)
    m1.scatter(m1.comm_world(), list("wxyz"), root=1)   # in flight
    c.checkpoint(1, {"x": np.arange(2.0)}, None).wait()
    m0 = c.mana(0)
    assert m0.pending_messages                 # the drained scatter chunk
    assert m0.iprobe() is None                 # drained internal: invisible
    m1.isend(0, tag=4, payload="user")
    assert m0.iprobe() == (1, 4 + 50000)       # user message still probes
    # live internal traffic is equally invisible to the wildcard probe
    m2 = c.mana(2)
    c.mana(3).isend(2, tag=0, payload="u2")
    m1.bcast(m1.comm_world(), "live", root=1)  # live coll msg ahead in queue
    probe = m2.iprobe()
    assert probe is None or probe == (3, 50000)
    c.writer.close()


def test_membership_change_redelivers_or_cancels_never_drops(tmp_path):
    """Drain under membership change (staggered scatter): in-flight traffic
    addressed to a departing rank is either REDELIVERED through its state
    inheritor's buffered receive (user p2p) or CANCELLED with a typed
    record (internal collective chunks — their round dies with the old
    membership) — never silently dropped.  New sends to the departed rank
    fail with a typed transport error."""
    from repro.core import elastic
    from repro.core.backends.fabric import DepartedRankError
    c = Cluster(WORLD, "mpich", ckpt_dir=tmp_path / "ck")
    m1 = c.mana(1)
    # staggered: root entered the scatter, peers have not — one chunk per
    # peer is in flight, including one addressed to the leaver
    m1.scatter(m1.comm_world(), [f"s{q}" for q in range(WORLD)], root=1)
    c.mana(0).isend(3, tag=6, payload="user-for-leaver")
    rep = elastic.shrink(c, 3, timeout=5.0)
    # the leaver's scatter chunk: typed cancellation + a cluster event
    assert any(t >= 1 << 32 for (_, t) in rep.cancelled)
    assert any(e[0] == "rescale_cancelled_msgs" and e[1] == 3
               for e in c.events)
    # the user message re-delivers at the inheritor with original metadata
    assert rep.redelivered >= 1
    assert c.mana(rep.inheritor).recv(0, 6) == "user-for-leaver"
    # the p2p plane is clean post-shrink: a fresh collective round over the
    # new membership completes, and sends to the departed rank are typed
    got = run_coll(c, lambda m: m.scatter(
        m.comm_world(), list("abc") if m.rank == 1 else None, root=1),
        ranks=[0, 1, 2])
    assert got == ["a", "b", "c"]
    with pytest.raises(DepartedRankError):
        c.mana(2).isend(3, tag=1, payload="ghost")
    c.writer.close()


def test_drain_counts_collective_traffic():
    c = Cluster(2, "openmpi")
    m0, m1 = c.mana(0), c.mana(1)
    m0.isend(1, tag=1, payload="user")
    m0.bcast(m0.comm_world(), "coll", root=0)
    st = drain_rank(m1)
    assert st["messages_buffered"] == 2
    assert st["coll_messages_buffered"] == 1
    assert m1.bcast(m1.comm_world(), None, root=0) == "coll"
    assert m1.recv(0, 1) == "user"


# ---------------------------------------------------------------------------
# waitany / waitsome
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["mpich", "fabric"])
def test_waitany_waitsome(backend):
    c = Cluster(2, backend)
    m0 = c.mana(0)
    reqs = [m0.isend(1, tag=t, payload=t) for t in range(3)]
    assert m0.waitany(reqs) == 0
    assert m0.waitsome(reqs) == [0, 1, 2]
    assert m0.waitsome([]) == []
    with pytest.raises(ValueError):
        m0.waitany([])
    # completion mirrored into descriptors, so the drain sees them done
    assert all(m0._desc(r).state["done"] for r in reqs)


# ---------------------------------------------------------------------------
# typed free errors (the request_free corruption fix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("translation", ["fast", "slow"])
def test_request_free_double_free_is_typed(translation):
    c = Cluster(2, "mpich", translation=translation)
    m = c.mana(0)
    h = m.isend(1, tag=1, payload="p")
    m.request_free(h)
    with pytest.raises(HandleFreeError):
        m.request_free(h)
    # the table survived intact: new registrations still work
    h2 = m.isend(1, tag=2, payload="q")
    assert m.test(h2) is True


def test_request_free_wrong_kind_and_unknown():
    c = Cluster(2, "openmpi")
    m = c.mana(0)
    with pytest.raises(HandleFreeError):
        m.request_free(m.comm_world())          # a COMM, not a REQUEST
    from repro.core.callspec import make_handle
    from repro.core.vid import pack_vid
    with pytest.raises(HandleFreeError):
        m.request_free(make_handle(pack_vid(Kind.REQUEST, 12345)))
    with pytest.raises(HandleFreeError):
        m.comm_free(m.isend(1, tag=1, payload="x"))  # REQUEST into comm_free


def test_handle_kind_checked_on_entry():
    c = Cluster(2, "mpich")
    m = c.mana(0)
    with pytest.raises(HandleKindError):
        m.comm_size(m.dtype_handles["MPI_FLOAT"])
    with pytest.raises(HandleKindError):
        m.test(m.comm_world())


# ---------------------------------------------------------------------------
# registry/coverage gates double as tier-1 tests
# ---------------------------------------------------------------------------

def test_every_wrapper_is_generated():
    from repro.core.interpose import Mana
    for spec in REGISTRY:
        fn = getattr(Mana, spec.name)
        assert getattr(fn, "__callspec__", None) is spec, spec.name


def test_registry_policies_and_gates():
    assert spec_for("comm_split").policy is Policy.CREATES
    assert spec_for("isend").drains and spec_for("grequest_start").drains
    assert set(COLLECTIVE_CALLS) >= {"bcast", "reduce", "allreduce",
                                     "scatter", "gather", "allgather",
                                     "reduce_scatter", "scan", "alltoall"}
    for name in COLLECTIVE_CALLS:
        spec = spec_for(name)
        if spec.capability is not None:
            assert spec.fallback is not None, name


def test_api_coverage_tool_passes():
    import importlib.util
    from pathlib import Path
    p = Path(__file__).resolve().parent.parent / "tools" \
        / "check_api_coverage.py"
    sp = importlib.util.spec_from_file_location("check_api_coverage", p)
    mod = importlib.util.module_from_spec(sp)
    sp.loader.exec_module(mod)
    assert mod.check() == []


def test_restart_shim_removed():
    # the deprecated repro.core.restart alias is gone — the restart engine
    # is importable only as repro.core.restore
    import importlib
    import sys
    sys.modules.pop("repro.core.restart", None)
    with pytest.raises(ModuleNotFoundError):
        importlib.import_module("repro.core.restart")
    importlib.import_module("repro.core.restore")
