"""ckpt_io engine: codecs, chunked shard container, digests, incremental
delta chains, GC dependency protection, parallel restore, legacy v1 images,
and bf16 round-trips."""
import json
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CkptIOConfig
from repro.core import Cluster, ckpt_io
from repro.core.ckpt import CheckpointWriter
from repro.core.restore import load_arrays, load_manifest


# ---------------------------------------------------------------------------
# codec layer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec_name", ["none", "zlib"])
@pytest.mark.parametrize("arr", [
    np.arange(1000, dtype=np.float32).reshape(10, 100),
    np.zeros((513, 7), np.float64),             # compressible, odd shape
    np.array(3.5, np.float32),                  # 0-d
    np.zeros((0, 4), np.int32),                 # empty
    np.arange(5, dtype=np.int64),
    np.random.default_rng(0).normal(size=2048).astype(np.float32),  # noise
], ids=["ramp", "zeros", "scalar", "empty", "ints", "noise"])
def test_lossless_roundtrip(tmp_path, codec_name, arr):
    codec = ckpt_io.get_codec(codec_name)
    ckpt_io.write_rank_shards(tmp_path, {"x": arr}, codec, chunk_bytes=1024)
    out = ckpt_io.read_rank_entries(tmp_path, ["x"])["x"]
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


def test_bfloat16_roundtrip_shard_container(tmp_path):
    import ml_dtypes
    arr = np.arange(37, dtype=ml_dtypes.bfloat16)
    ckpt_io.write_rank_shards(tmp_path, {"x": arr}, ckpt_io.get_codec("zlib"))
    out = ckpt_io.read_rank_entries(tmp_path, ["x"])["x"]
    assert out.dtype == np.dtype(ml_dtypes.bfloat16)
    np.testing.assert_array_equal(out.astype(np.float32),
                                  arr.astype(np.float32))


def test_int8_codec_lossy_floats_lossless_ints(tmp_path):
    rng = np.random.default_rng(1)
    f = rng.normal(size=512).astype(np.float32)
    i = rng.integers(-5, 5, 64).astype(np.int32)
    codec = ckpt_io.get_codec("int8")
    st = ckpt_io.write_rank_shards(tmp_path, {"f": f, "i": i}, codec)
    out = ckpt_io.read_rank_entries(tmp_path, ["f", "i"])
    # floats: quantized within one step of the per-tensor scale
    scale = max(np.abs(f).max(), 1e-12) / 127.0
    assert out["f"].dtype == np.float32
    np.testing.assert_allclose(out["f"], f, atol=scale * 1.01)
    # ints pass through untouched
    np.testing.assert_array_equal(out["i"], i)
    # the quantized payload is 4x smaller than the raw floats
    assert st["entries"]["f"]["nbytes"] == f.nbytes // 4


def test_lz4_codec_gated():
    try:
        import lz4.frame  # noqa: F401
        has_lz4 = True
    except ImportError:
        has_lz4 = False
    if has_lz4:
        assert ckpt_io.get_codec("lz4").name == "lz4"
    else:
        with pytest.raises(ImportError, match="lz4"):
            ckpt_io.get_codec("lz4")


def test_unknown_codec():
    with pytest.raises(KeyError, match="unknown checkpoint codec"):
        ckpt_io.get_codec("zstd-77")


def test_chunking_splits_and_reassembles(tmp_path):
    arr = np.arange(10000, dtype=np.float32)      # 40 KB over 1 KB chunks
    ckpt_io.write_rank_shards(tmp_path, {"x": arr},
                              ckpt_io.get_codec("none"), chunk_bytes=1024)
    idx = ckpt_io.read_rank_index(tmp_path)
    assert len(idx["entries"]["x"]["chunks"]) == 40
    out = ckpt_io.read_rank_entries(tmp_path, ["x"])["x"]
    np.testing.assert_array_equal(out, arr)


def test_adaptive_probe_stores_noise_raw(tmp_path):
    rng = np.random.default_rng(2)
    noise = rng.normal(size=65536).astype(np.float32)
    zeros = np.zeros(65536, np.float32)
    ckpt_io.write_rank_shards(tmp_path, {"n": noise, "z": zeros},
                              ckpt_io.get_codec("zlib"))
    idx = ckpt_io.read_rank_index(tmp_path)
    # noise fails the entropy probe -> stored raw (flag 1, enc == raw)
    n_entry = idx["entries"]["n"]
    assert all(c[2] == 1 and c[0] == c[1] for c in n_entry["chunks"])
    # zeros pass -> compressed hard
    z_entry = idx["entries"]["z"]
    assert all(c[2] == 0 for c in z_entry["chunks"])
    assert sum(c[0] for c in z_entry["chunks"]) < zeros.nbytes // 100


def test_shard_digest_qualifies_dtype_and_shape():
    a = np.arange(6, dtype=np.float32)
    assert ckpt_io.shard_digest(a) == ckpt_io.shard_digest(a.copy())
    assert ckpt_io.shard_digest(a) != ckpt_io.shard_digest(a.reshape(2, 3))
    assert ckpt_io.shard_digest(a) != ckpt_io.shard_digest(
        a.view(np.int32))
    assert ckpt_io.shard_digest(a) != ckpt_io.shard_digest(a + 1)


def test_inline_digest_matches_shard_digest(tmp_path):
    arr = np.arange(5000, dtype=np.float32)
    st = ckpt_io.write_rank_shards(tmp_path, {"x": arr},
                                   ckpt_io.get_codec("zlib"),
                                   chunk_bytes=4096, compute_digests=True)
    assert st["digests"]["x"] == ckpt_io.shard_digest(arr)


def test_resolve_dtype():
    import ml_dtypes
    assert ckpt_io.resolve_dtype("float32") == np.float32
    assert ckpt_io.resolve_dtype("bfloat16") == np.dtype(ml_dtypes.bfloat16)
    assert ckpt_io.resolve_dtype("float8_e4m3fn") == np.dtype(
        ml_dtypes.float8_e4m3fn)
    with pytest.raises(TypeError, match="cannot resolve"):
        ckpt_io.resolve_dtype("not_a_dtype")


# ---------------------------------------------------------------------------
# writer: incremental delta chains + GC
# ---------------------------------------------------------------------------

def _writer(tmp_path, **kw):
    return CheckpointWriter(tmp_path / "ck", world_size=2, **kw)


def test_incremental_second_checkpoint_writes_under_20pct(tmp_path):
    w = _writer(tmp_path, codec="zlib", incremental=True)
    arrays = {"a": jnp.asarray(np.random.default_rng(0)
                               .normal(size=(64, 64)).astype(np.float32))}
    st1 = w.checkpoint(1, arrays, None, {}).wait()
    st2 = w.checkpoint(2, arrays, None, {}).wait()
    assert st1["full"] and not st2["full"]
    assert st2["bytes_written"] < 0.2 * st1["bytes_written"]
    assert st2["fresh_shards"] == 0
    man = load_manifest(w.latest())
    assert man["base_steps"] == [1]
    out = load_arrays(w.latest(), {"a": None})
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(arrays["a"]))
    w.close()


def test_incremental_dirty_shard_rewritten(tmp_path):
    w = _writer(tmp_path, incremental=True)
    a = np.arange(16.0, dtype=np.float32)
    w.checkpoint(1, {"a": jnp.asarray(a), "b": jnp.zeros(4)}, None, {}).wait()
    st = w.checkpoint(2, {"a": jnp.asarray(a + 1), "b": jnp.zeros(4)},
                      None, {}).wait()
    assert st["fresh_shards"] == 1 and st["total_shards"] == 2
    out = load_arrays(w.latest(), {"a": None, "b": None})
    np.testing.assert_array_equal(np.asarray(out["a"]), a + 1)
    w.close()


def test_full_checkpoint_every_keep_bounds_chain(tmp_path):
    w = _writer(tmp_path, incremental=True, keep=3)
    arrays = {"a": jnp.arange(8.0)}
    fulls = []
    for step in range(1, 8):
        st = w.checkpoint(step, arrays, None, {}).wait()
        fulls.append(st["full"])
    # full at 1, then deltas until since_full reaches keep: full at 4, 7
    assert fulls == [True, False, False, True, False, False, True]
    w.close()


def test_gc_preserves_delta_dependencies(tmp_path):
    w = _writer(tmp_path, incremental=True, keep=3)
    arrays = {"a": jnp.arange(64.0)}
    for step in range(1, 6):
        w.checkpoint(step, arrays, None, {}).wait()
    names = sorted(p.name for p in w.base.iterdir())
    # keep=3 -> steps 3,4,5 kept; step 3 is a delta on the step-1 full, and
    # 5 on the step-4 full, so step 1 MUST survive GC
    assert "step_00000001" in names
    assert "step_00000002" not in names
    # every kept delta restores bit-identically
    for d in [p for p in w.base.iterdir() if (p / "COMMIT").exists()]:
        out = load_arrays(d, {"a": None})
        np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(64.0))
    w.close()


def test_gc_deletes_unreferenced_when_chain_rolls_over(tmp_path):
    w = _writer(tmp_path, incremental=True, keep=2)
    arrays = {"a": jnp.arange(8.0)}
    for step in range(1, 8):
        w.checkpoint(step, arrays, None, {}).wait()
    names = {p.name for p in w.base.iterdir()}
    kept_steps = sorted(int(n.split("_")[1]) for n in names)
    # last keep=2 steps plus whatever full they depend on, nothing else
    assert 7 in kept_steps and 6 in kept_steps
    assert len(kept_steps) <= 4
    for d in sorted(w.base.iterdir()):
        man = load_manifest(d)
        for dep in man["base_steps"]:
            assert (w.base / f"step_{dep:08d}" / "COMMIT").exists()
    w.close()


def test_keep_zero_retains_everything(tmp_path):
    """Seed semantics: keep<=0 means GC never deletes."""
    w = _writer(tmp_path, keep=0)
    for step in (1, 2, 3, 4):
        w.checkpoint(step, {"x": jnp.zeros(2)}, None, {}).wait()
    commits = [p for p in w.base.iterdir() if (p / "COMMIT").exists()]
    assert len(commits) == 4
    assert w.latest().name == "step_00000004"
    w.close()


def test_cluster_conflicting_keep_rejected(tmp_path):
    with pytest.raises(ValueError, match="conflicting retention"):
        Cluster(2, "mpich", ckpt_dir=tmp_path / "ck", keep=5,
                ckpt_io=CkptIOConfig(keep=3))


def test_force_full_next(tmp_path):
    w = _writer(tmp_path, incremental=True)
    arrays = {"a": jnp.arange(8.0)}
    w.checkpoint(1, arrays, None, {}).wait()
    w.force_full_next()
    st = w.checkpoint(2, arrays, None, {}).wait()
    assert st["full"] and st["fresh_shards"] == st["total_shards"]
    w.close()


def test_latest_skips_tmp_and_uncommitted(tmp_path):
    w = _writer(tmp_path)
    w.checkpoint(1, {"a": jnp.zeros(2)}, None, {}).wait()
    # interrupted write: dir exists, no COMMIT
    broken = w.base / "step_00000009"
    broken.mkdir()
    (broken / "manifest.json").write_text("{}")
    # half-renamed tmp dir
    (w.base / "step_00000010.tmp").mkdir()
    assert w.latest().name == "step_00000001"
    assert [d.name for d in w._completed_steps()] == ["step_00000001"]
    w.close()


def test_gc_keep_semantics_ignores_tmp(tmp_path):
    w = _writer(tmp_path, keep=2)
    (w.base / "step_00000000.tmp").mkdir()
    for step in (1, 2, 3, 4):
        w.checkpoint(step, {"x": jnp.zeros(2)}, None, {}).wait()
    commits = [p.name for p in w.base.iterdir() if (p / "COMMIT").exists()]
    assert sorted(commits) == ["step_00000003", "step_00000004"]
    # .tmp dir is not GC'd (it is invisible to the scan), not counted
    assert (w.base / "step_00000000.tmp").exists()
    w.close()


# ---------------------------------------------------------------------------
# restore: parallel loader, elastic + incremental + compressed, legacy v1
# ---------------------------------------------------------------------------

def test_elastic_restart_from_incremental_compressed(tmp_path):
    cfg = CkptIOConfig(codec="zlib", incremental=True)
    cluster = Cluster(4, "craympi", ckpt_dir=tmp_path / "ck", ckpt_io=cfg)
    arrays = {"w": jnp.asarray(np.random.default_rng(3)
                               .normal(size=(32, 16)).astype(np.float32)),
              "b": jnp.arange(10, dtype=jnp.int32)}
    cluster.checkpoint(1, arrays, None).wait()
    st2 = cluster.checkpoint(2, arrays, None).wait()
    assert st2["bytes_written"] < 0.2 * max(st2["bytes_total"], 1)
    # elastic: restart the DELTA checkpoint onto a different world size
    fresh = cluster.restart(cluster.writer.latest(), new_world_size=2)
    assert fresh.world_size == 2
    out = load_arrays(cluster.writer.latest(), {"w": None, "b": None})
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(arrays["w"]))
    np.testing.assert_array_equal(np.asarray(out["b"]),
                                  np.asarray(arrays["b"]))
    # the restarted cluster's own writer starts a fresh chain: full first
    st3 = fresh.checkpoint(3, arrays, None).wait()
    assert st3["full"]


def test_bfloat16_leaf_checkpoint_restore(tmp_path):
    """Regression: np.dtype('bfloat16') raises in plain numpy; the loader
    must resolve it via ml_dtypes."""
    cluster = Cluster(2, "mpich", ckpt_dir=tmp_path / "ck")
    arr = jnp.asarray(np.arange(24, dtype=np.float32) / 8,
                      dtype=jnp.bfloat16)
    cluster.checkpoint(1, {"p": arr}, None).wait()
    out = load_arrays(cluster.writer.latest(), {"p": None})
    assert out["p"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["p"], dtype=np.float32),
                                  np.asarray(arr, dtype=np.float32))


def test_restore_parallel_workers_match_serial(tmp_path):
    w = _writer(tmp_path, codec="zlib")
    arrays = {"a": jnp.asarray(np.random.default_rng(5)
                               .normal(size=(128, 32)).astype(np.float32))}
    w.checkpoint(1, arrays, None, {}).wait()
    a1 = load_arrays(w.latest(), {"a": None}, io_workers=1)
    a4 = load_arrays(w.latest(), {"a": None}, io_workers=4)
    np.testing.assert_array_equal(np.asarray(a1["a"]), np.asarray(a4["a"]))
    w.close()


def _make_legacy_v1_ckpt(base, arrays):
    """Hand-build a seed-format (v1) checkpoint: monolithic npz per rank,
    manifest without a ``format`` field."""
    step_dir = base / "step_00000005"
    rdir = step_dir / "rank00000"
    rdir.mkdir(parents=True)
    leaves, _ = jax.tree.flatten(arrays)
    per_rank = {}
    leaves_meta = []
    for li, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        key = f"{li}.0"
        per_rank[key] = arr
        leaves_meta.append({
            "shape": list(arr.shape), "dtype": ckpt_io.dtype_name(arr.dtype),
            "shards": [{"rank": 0, "key": key,
                        "file": "rank00000/arrays.npz",
                        "index": [[0, s] for s in arr.shape]}]})
    np.savez(rdir / "arrays.npz", **per_rank)
    (rdir / "state.json").write_text("{}")
    (step_dir / "manifest.json").write_text(json.dumps({
        "step": 5, "world_size": 1, "mesh": None, "leaves": leaves_meta}))
    (step_dir / "COMMIT").write_text("ok")
    return step_dir


def test_legacy_v1_npz_checkpoint_still_loads(tmp_path):
    arrays = {"a": jnp.arange(12.0).reshape(3, 4),
              "b": jnp.ones((5,), jnp.int32)}
    ck = _make_legacy_v1_ckpt(tmp_path, arrays)
    out = load_arrays(ck, jax.tree.map(lambda x: None, arrays))
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(arrays["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]),
                                  np.asarray(arrays["b"]))


def test_npz_cache_bounded_and_closed(tmp_path):
    from repro.core.restore import _NpzCache
    paths = []
    for i in range(6):
        p = tmp_path / f"f{i}.npz"
        np.savez(p, x=np.arange(4))
        paths.append(p)
    cache = _NpzCache(cap=2)
    handles = [cache.get(p) for p in paths]
    # only cap handles stay open; evicted ones are closed
    assert len(cache._od) == 2
    closed = 0
    for h in handles[:-2]:
        try:
            h["x"]
        except Exception:  # noqa: BLE001
            closed += 1
    assert closed == 4
    cache.close()
    assert len(cache._od) == 0


def test_corrupt_shard_file_fails_loud(tmp_path):
    w = _writer(tmp_path, codec="zlib")
    w.checkpoint(1, {"a": jnp.zeros((512,))}, None, {}).wait()
    ck = w.latest()
    binf = ck / "rank00000" / ckpt_io.BIN_NAME
    binf.write_bytes(binf.read_bytes()[:10])   # truncate
    with pytest.raises(Exception):
        load_arrays(ck, {"a": None})
    w.close()


def test_write_error_surfaces_on_wait(tmp_path):
    w = _writer(tmp_path)
    req = w.checkpoint(1, {"a": jnp.zeros(2)}, None, {})
    req.wait()
    # make the base dir unwritable-ish by replacing it with a file
    shutil.rmtree(w.base)
    w.base.write_text("not a dir")
    req2 = w.checkpoint(2, {"a": jnp.zeros(2)}, None, {})
    with pytest.raises(Exception):
        req2.wait()


# ---------------------------------------------------------------------------
# crash-atomicity: kill-mid-append + torn index publish (chaos hardening)
# ---------------------------------------------------------------------------

def test_kill_mid_append_leaves_previous_ckpt_resumable(tmp_path):
    """A process death inside RankShardWriter.add (the ckpt_io.append
    failpoint) must never poison resume: the half-written step stays
    uncommitted and resume-from-latest lands on the previous good one."""
    from repro.core import faults
    from repro.core.restore import find_resumable

    w = _writer(tmp_path, codec="zlib", incremental=True)
    arrays = {"a": jnp.asarray(np.arange(4096, dtype=np.float32)),
              "b": jnp.asarray(np.ones((64, 8), np.float32))}
    w.checkpoint(1, arrays, None, {}).wait()
    good = w.latest()

    calls = []

    def die_on_second(name, ctx):
        calls.append(ctx["key"])
        if len(calls) >= 2:
            raise faults.InjectedFault("kill mid-append")

    faults.arm("ckpt_io.append", die_on_second)
    try:
        arrays2 = {k: v + 1 for k, v in arrays.items()}
        req = w.checkpoint(2, arrays2, None, {})
        with pytest.raises(Exception):
            req.wait()
    finally:
        faults.disarm("ckpt_io.append")
    # the failed step never published: no COMMIT, invisible to scans
    assert w.latest() == good
    assert find_resumable(tmp_path / "ck") == good
    out = load_arrays(good, {"a": None, "b": None})
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(arrays["a"]))
    w.close()


def test_index_publish_is_atomic(tmp_path):
    """finish() publishes index.json via tmp + os.replace: no .tmp residue,
    and a handler dying between container writes and finish leaves NO
    index at all (unreadable dir) rather than a torn one."""
    codec = ckpt_io.get_codec("zlib")
    w = ckpt_io.RankShardWriter(tmp_path / "r0", codec)
    w.add("x", np.arange(100, dtype=np.float32))
    st = w.finish()
    assert (tmp_path / "r0" / ckpt_io.INDEX_NAME).exists()
    assert not (tmp_path / "r0" / (ckpt_io.INDEX_NAME + ".tmp")).exists()
    assert ckpt_io.read_rank_index(tmp_path / "r0")["entries"].keys() \
        == st["entries"].keys()


def test_atomic_write_text_replaces_not_truncates(tmp_path):
    p = tmp_path / "f.json"
    p.write_text("old")
    ckpt_io.atomic_write_text(p, "new contents")
    assert p.read_text() == "new contents"
    assert not p.with_name(p.name + ".tmp").exists()
