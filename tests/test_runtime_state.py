"""Runtime-state conformance suite (``repro.core.runtime_state``).

Fast tier: registry/skeleton/descriptor unit coverage plus the container's
``kind="runtime"`` tagging and delta-eligibility.

Slow tier (``-m slow``): the stateful-inference conformance sweep — a
mid-sequence xLSTM / SSM generation is snapshotted, restored on a FRESH
server (no prefill: the snapshot's runtime section carries the cache
treedef) under every one of the 25 ordered backend pairs, and the
continued token stream must be byte-identical to an uninterrupted run —
the "develop once, run everywhere" claim extended from params to live
decode state.
"""
import json
from dataclasses import replace
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import ckpt, runtime_state as RS
from repro.core.backends import BACKENDS
from repro.core.restore import translation_plan

FLAVORS = sorted(BACKENDS)
PAIRS = [(s, d) for s in FLAVORS for d in FLAVORS]


# ---------------------------------------------------------------------------
# fast: skeletons + descriptors
# ---------------------------------------------------------------------------

def test_skeleton_roundtrip_matches_flatten_order():
    tree = {"b": [np.zeros(2), (np.ones(3), np.zeros(1))],
            "a": {"y": np.zeros(4), "x": np.zeros(5)},
            "c": None}
    skel = RS.tree_skeleton(tree)
    assert RS.skeleton_leaf_count(skel) == len(jax.tree.leaves(tree))
    # filling with a counter must enumerate leaves in jax flatten order
    it = iter(range(10))
    rebuilt = RS.skeleton_fill(skel, lambda: next(it))
    flat, treedef = jax.tree.flatten(rebuilt)
    assert flat == list(range(len(flat)))
    ref_flat, ref_treedef = jax.tree.flatten(tree)
    assert treedef == ref_treedef
    nulls = RS.null_tree(skel)
    assert all(x is None for x in jax.tree.flatten(
        nulls, is_leaf=lambda x: x is None)[0])


def test_state_leaf_json_roundtrip():
    leaf = RS.StateLeaf(name="kv/3", dtype="bfloat16", shape=(2, 4, 8),
                        layout="sharded", mpi_dtype="MPI_BFLOAT16")
    assert RS.StateLeaf.from_json(leaf.to_json()) == leaf


def test_describe_tree_transport_dtypes():
    import ml_dtypes
    tree = {"a": np.zeros(3, np.int8), "b": np.zeros((), np.float32),
            "c": np.zeros(2, ml_dtypes.float8_e4m3fn)}
    leaves = RS.describe_tree("p", tree)
    by_name = {l.name: l for l in leaves}
    assert by_name["p/0"].mpi_dtype == "MPI_INT8_T"
    assert by_name["p/1"].mpi_dtype == "MPI_FLOAT"
    assert by_name["p/1"].shape == ()
    assert by_name["p/2"].mpi_dtype == "MPI_CHAR"   # no MPI constant: bytes


# ---------------------------------------------------------------------------
# fast: registry snapshot/restore
# ---------------------------------------------------------------------------

def _registry(state):
    reg = RS.RuntimeStateRegistry()
    reg.register(RS.PyTreeProvider("caches", lambda: state["caches"],
                                   lambda t: state.__setitem__("caches", t)))
    reg.register(RS.RngStateProvider("rng", lambda: state["rng"],
                                     lambda k: state.__setitem__("rng", k)))
    reg.register(RS.JsonStateProvider("cursor", lambda: state["cursor"],
                                      lambda c: state.__setitem__("cursor",
                                                                  c)))
    return reg


def test_registry_roundtrip():
    state = {"caches": {"k": np.arange(6, dtype=np.float32).reshape(2, 3),
                        "v": (np.ones(2, np.int8), np.zeros(1))},
             "rng": jax.random.key(7),
             "cursor": {"pos": 11, "last_tok": [3, 4]}}
    reg = _registry(state)
    arrays, meta = reg.snapshot()
    # JSON round-trip the meta: it rides state.json
    meta = json.loads(json.dumps(meta))
    assert set(arrays) == {"caches", "rng"}       # cursor has no leaves
    sh = reg.shardings(meta)
    # the null-sharding tree mirrors the cache structure (None at leaves —
    # flatten with is_leaf exactly as load_arrays does)
    assert jax.tree.structure(sh["caches"],
                              is_leaf=lambda x: x is None) == \
        jax.tree.structure(state["caches"])

    target = {"caches": None, "rng": None, "cursor": None}
    reg2 = _registry(target)
    stats = reg2.restore(arrays, meta)
    assert stats["providers"] == 3 and not stats["skipped"]
    np.testing.assert_array_equal(target["caches"]["k"],
                                  state["caches"]["k"])
    assert np.asarray(jax.random.key_data(target["rng"])).tobytes() == \
        np.asarray(jax.random.key_data(state["rng"])).tobytes()
    assert target["cursor"] == {"pos": 11, "last_tok": [3, 4]}


def test_registry_empty_provider_and_unknown_skip():
    state = {"caches": None, "rng": jax.random.key(0), "cursor": {}}
    reg = _registry(state)
    arrays, meta = reg.snapshot()
    assert "caches" not in arrays                  # empty cache: no leaves
    assert meta["providers"]["caches"]["meta"] == {"empty": True}

    lone = RS.RuntimeStateRegistry()
    got = {}
    lone.register(RS.RngStateProvider("rng", lambda: None,
                                      lambda k: got.setdefault("rng", k)))
    stats = lone.restore(arrays, meta)
    assert stats["providers"] == 1
    assert sorted(stats["skipped"]) == ["caches", "cursor"]


def test_registry_version_guard():
    reg = RS.RuntimeStateRegistry()
    reg.register(RS.JsonStateProvider("cursor", dict, lambda c: None,
                                      version=1))
    meta = {"format": RS.FORMAT,
            "providers": {"cursor": {"version": 2, "meta": {"state": {}}}}}
    with pytest.raises(ValueError, match="newer"):
        reg.restore({}, meta)


def test_reencode_through_pair_plan():
    leaves = [RS.StateLeaf("p/0", "int8", (4,),
                           mpi_dtype="MPI_INT8_T").to_json(),
              RS.StateLeaf("p/1", "float32", (2,),
                           mpi_dtype="MPI_FLOAT").to_json()]
    # ExaMPI reinterpret-casts INT8 to CHAR: the runtime section re-encodes
    # exactly like datatype envelopes
    plan = translation_plan("mpich", "exampi")
    assert plan.runtime["reencode"]
    out, n = RS.reencode_leaves(leaves, plan)
    assert n == 1 and out[0]["mpi_dtype"] == "MPI_CHAR"
    assert out[1]["mpi_dtype"] == "MPI_FLOAT"
    # same-discipline destination: identity
    plan2 = translation_plan("mpich", "mpich")
    out2, n2 = RS.reencode_leaves(leaves, plan2)
    assert n2 == 0 and out2 == leaves


# ---------------------------------------------------------------------------
# fast: container kind="runtime" tagging + delta eligibility
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pipeline", [True, False],
                         ids=["pipelined", "buffered"])
def test_kind_runtime_entries_delta_eligible(tmp_path, pipeline):
    w = ckpt.CheckpointWriter(tmp_path, 1, codec="zlib", incremental=True,
                              pipeline=pipeline)
    arrays = {"params": {"w": np.ones((4, 4), np.float32)},
              "runtime": {"kv": np.zeros((2, 3), np.float32),
                          "rng": np.asarray([0, 7], np.uint32)}}
    w.checkpoint(1, arrays, None, {0: {}}).wait()
    d1 = tmp_path / "step_00000001"
    index = json.loads((d1 / "rank00000" / "index.json").read_text())
    manifest = json.loads((d1 / "manifest.json").read_text())
    # flatten order is sorted-key: params.w, runtime.kv, runtime.rng
    assert "kind" not in index["entries"]["0.0"]
    assert index["entries"]["1.0"]["kind"] == "runtime"
    assert index["entries"]["2.0"]["kind"] == "runtime"
    assert "kind" not in manifest["leaves"][0]
    assert manifest["leaves"][1]["kind"] == "runtime"
    assert manifest["leaves"][2]["kind"] == "runtime"
    # digest-fused: runtime entries carry content digests like any leaf
    assert all(index["entries"][k]["digest"] for k in index["entries"])
    # delta-eligible: unchanged runtime shards are NOT rewritten
    w.checkpoint(2, arrays, None, {0: {}}).wait()
    m2 = json.loads(
        (tmp_path / "step_00000002" / "manifest.json").read_text())
    assert m2["delta"]["fresh_shards"] == 0
    # a mutated runtime leaf IS rewritten, tagged, and re-pointed
    arrays["runtime"]["rng"] = np.asarray([1, 8], np.uint32)
    w.checkpoint(3, arrays, None, {0: {}}).wait()
    d3 = tmp_path / "step_00000003"
    m3 = json.loads((d3 / "manifest.json").read_text())
    i3 = json.loads((d3 / "rank00000" / "index.json").read_text())
    assert m3["delta"]["fresh_shards"] == 1
    assert i3["entries"]["2.0"]["kind"] == "runtime"
    assert m3["leaves"][1]["shards"][0]["step"] == 1   # clean kv re-pointed
    w.close()


def test_runtime_leaf_indices():
    arrays = {"params": {"a": 1, "b": [2, 3]},
              "runtime": {"kv": {"x": 4}, "rng": 5}}
    assert ckpt.runtime_leaf_indices(arrays) == frozenset({3, 4})
    assert ckpt.runtime_leaf_indices({"params": {"a": 1}}) == frozenset()
    assert ckpt.runtime_leaf_indices([1, 2]) == frozenset()


# ---------------------------------------------------------------------------
# slow: the 25-pair stateful-inference conformance sweep
# ---------------------------------------------------------------------------

WORLD, PROMPT, GEN, SNAP = 2, 6, 8, 3

ARCH_CFGS = {
    # xLSTM recurrent caches: {"C","n","m","conv"} dicts per block
    "xlstm": lambda: replace(smoke_config("xlstm-350m"), n_layers=2,
                             d_model=64),
    # hybrid SSM: {"state","conv"} dicts + KV caches in one tree
    "ssm": lambda: replace(smoke_config("hymba-1.5b"), n_layers=2),
}


class _Rig:
    """Lazily-built source runs and restorer servers, shared module-wide so
    each (arch, src) pair compiles and decodes its reference stream once."""

    def __init__(self, base: Path):
        self.base = base
        self._sources: dict = {}
        self._restorers: dict = {}
        self._servers: list = []

    def _prompts(self, cfg):
        rng = np.random.default_rng(0)
        return rng.integers(0, cfg.vocab_size, (2, PROMPT), dtype=np.int32)

    def source(self, arch: str, flavor: str):
        """(ckpt_dir, reference tail stream, reference final rng key) of a
        mid-sequence generation snapshotted at SNAP decoded tokens and run
        to GEN without interruption."""
        key = (arch, flavor)
        if key not in self._sources:
            from repro.serving.engine import Server
            cfg = ARCH_CFGS[arch]()
            srv = Server(cfg, world_size=WORLD, backend=flavor,
                         ckpt_dir=self.base / f"{arch}_{flavor}", seed=0)
            self._servers.append(srv)
            logits = srv.prefill(self._prompts(cfg), None,
                                 pad_to=PROMPT + GEN + 1)
            first = np.argmax(np.asarray(logits)[..., : cfg.vocab_size],
                              axis=-1).astype(np.int32)
            srv.start_decode(first)
            for _ in range(SNAP):
                srv.step_once()
            srv.checkpoint().wait()
            ck = srv.cluster.writer.latest()
            manifest = json.loads((ck / "manifest.json").read_text())
            assert all(m.get("kind") == "runtime"
                       for m in manifest["leaves"]), \
                "serving snapshot has untagged runtime leaves"
            for _ in range(GEN - SNAP):
                srv.step_once()
            tail = np.stack(srv.generated[SNAP:])
            rng_end = np.asarray(jax.random.key_data(srv.rng_key))
            self._sources[key] = (ck, tail, rng_end)
        return self._sources[key]

    def restorer(self, arch: str, flavor: str):
        """A fresh server that NEVER ran a prefill — reused across the 5
        destination flavors of one source (each restore must fully rewind
        it, exercising the replay-rewind path on later pairs)."""
        key = (arch, flavor)
        if key not in self._restorers:
            from repro.serving.engine import Server
            srv = Server(ARCH_CFGS[arch](), world_size=WORLD, backend=flavor,
                         ckpt_dir=self.base / f"{arch}_{flavor}_restorer",
                         seed=0)
            self._servers.append(srv)
            self._restorers[key] = srv
        return self._restorers[key]

    def close(self):
        for srv in self._servers:
            try:
                if srv.cluster.writer is not None:
                    srv.cluster.writer.close()
            except Exception:  # noqa: BLE001 — teardown must not mask
                pass


@pytest.fixture(scope="module")
def rig(tmp_path_factory):
    r = _Rig(tmp_path_factory.mktemp("runtime_state"))
    yield r
    r.close()


@pytest.mark.slow
@pytest.mark.parametrize("arch", sorted(ARCH_CFGS))
@pytest.mark.parametrize("src,dst", PAIRS,
                         ids=[f"{s}->{d}" for s, d in PAIRS])
def test_conformance_stream_byte_identical(rig, arch, src, dst):
    ck, ref_tail, ref_rng = rig.source(arch, src)
    srv = rig.restorer(arch, src)
    srv.restore(ck, new_backend=dst, rebuild=True)
    assert srv.cluster.backend_name == dst
    assert srv.pos == PROMPT + SNAP
    assert srv.resume_tok is not None and not srv.generated
    assert srv.last_runtime_restore["providers"] == 3
    srv.start_decode(srv.resume_tok)
    for _ in range(GEN - SNAP):
        srv.step_once()
    got = np.stack(srv.generated)
    # byte-identical continued stream: same tokens, same dtype, same bytes
    assert got.dtype == ref_tail.dtype and got.shape == ref_tail.shape
    assert got.tobytes() == ref_tail.tobytes(), \
        f"{arch} {src}->{dst}: continued stream diverged"
    # the RNG stream also continues bit-exactly
    assert np.asarray(jax.random.key_data(srv.rng_key)).tobytes() == \
        ref_rng.tobytes()
