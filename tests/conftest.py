# NOTE: deliberately NO --xla_force_host_platform_device_count here — smoke
# tests and benches must see the real single device; only launch/dryrun.py and
# the subprocess scenarios set up placeholder device fleets.
import jax
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: restart-matrix / chaos-adjacent tests — CI runs them in a "
        "separate tier-1 step (select with -m slow, skip with -m 'not slow')")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def assert_close(a, b, rtol=1e-4, atol=1e-4, msg=""):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol, err_msg=msg)
