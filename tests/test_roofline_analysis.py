"""Roofline analysis + dry-run artifact plumbing (pure functions, no devices)."""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).parents[1]))

from benchmarks.roofline import act_bytes_global, analyze
from repro.configs import get_config


def art(kind, flops, coll, arg_b, out_b, B, S, chips=256, n=1e9, tokens=None):
    return {
        "n_chips": chips, "kind": kind, "global_batch": B, "seq_len": S,
        "flops_global_mxu": flops,
        "collective_bytes_per_device": {"all-reduce": coll},
        "memory_analysis": {"argument_size_in_bytes": arg_b,
                            "output_size_in_bytes": out_b},
        "active_params": n,
        "tokens": tokens if tokens is not None else B * S,
    }


def test_analyze_terms_and_bottleneck():
    cfg = get_config("granite-3-2b")
    a = art("train", flops=2.5e16, coll=3.3e11, arg_b=1e8, out_b=1e8,
            B=256, S=4096, n=cfg.active_param_count())
    r = analyze(a, cfg)
    assert r["bottleneck"] == "collective"
    assert r["compute_s"] == pytest.approx(2.5e16 / (256 * 197e12))
    assert r["collective_s"] == pytest.approx(3.3e11 / 50e9)
    assert 0 < r["useful_ratio"] < 1.5
    # decode: no analytic activation traffic added
    d = art("decode", flops=1e13, coll=1e9, arg_b=4e9, out_b=4e9,
            B=128, S=32768, n=cfg.active_param_count(), tokens=128)
    rd = analyze(d, cfg)
    assert rd["memory_s"] == pytest.approx(8e9 / 819e9)


def test_act_bytes_scale_with_shape():
    cfg = get_config("granite-3-2b")
    t = act_bytes_global(cfg, "train", 256, 4096)
    t2 = act_bytes_global(cfg, "train", 256, 8192)
    assert t2 == pytest.approx(2 * t, rel=0.01)
    assert act_bytes_global(cfg, "decode", 128, 32768) == 0


def test_artifacts_cover_all_live_cells():
    """If the dry-run has been executed, every live cell must have artifacts
    for BOTH meshes (the multi-pod dry-run deliverable)."""
    from repro.configs import cells
    art_dir = Path(__file__).parents[1] / "artifacts" / "dryrun"
    if not art_dir.exists() or not any(art_dir.glob("*.json")):
        pytest.skip("dry-run artifacts not generated in this checkout")
    missing = []
    for arch, shape in cells():
        for mesh in ("pod", "multipod"):
            if not (art_dir / f"{arch}.{shape}.{mesh}.json").exists():
                missing.append(f"{arch}.{shape}.{mesh}")
    assert not missing, f"missing dry-run cells: {missing}"


def test_load_cells_missing_dir_raises_typed_error(tmp_path):
    from benchmarks.roofline import DryrunArtifactsError, load_cells
    with pytest.raises(DryrunArtifactsError) as ei:
        load_cells("pod", art_dir=tmp_path / "nope")
    # the message must tell the user how to get artifacts
    assert "--dryrun-dir" in str(ei.value)
    assert "dryrun_smoke" in str(ei.value)
    # present-but-empty directory: same typed error, different detail
    with pytest.raises(DryrunArtifactsError):
        load_cells("pod", art_dir=tmp_path)


def test_load_cells_smoke_fixture():
    from benchmarks.roofline import SMOKE_DIR, load_cells, render
    rows = load_cells("pod", art_dir=SMOKE_DIR)
    assert len(rows) >= 3
    for r in rows:
        assert 0 < r["roofline_fraction"] <= 1.0
        assert r["bottleneck"] in ("compute", "memory", "collective")
    assert "roofl%" in render(rows).splitlines()[0]


def test_roofline_cli_exit_codes(tmp_path, capsys):
    from benchmarks.roofline import SMOKE_DIR, main
    assert main(["--dryrun-dir", str(SMOKE_DIR)]) == 0
    assert "roofline," in capsys.readouterr().out
    assert main(["--dryrun-dir", str(tmp_path / "missing")]) == 2
    assert "roofline:" in capsys.readouterr().err


def test_artifact_sanity():
    import json
    art_dir = Path(__file__).parents[1] / "artifacts" / "dryrun"
    files = sorted(art_dir.glob("*.pod.json")) if art_dir.exists() else []
    if not files:
        pytest.skip("no artifacts")
    for f in files:
        a = json.loads(f.read_text())
        assert a["flops_global_mxu"] > 0, f.name
        assert a["compile_s"] > 0, f.name
        if a["kind"] == "train":
            # trip-aware FLOPs must exceed 2*N_active*tokens (fwd alone)
            assert a["flops_global_mxu"] > 2 * a["active_params"] * a["tokens"], f.name
